//! Point-to-point message matching: pair each `MpiRecv` instant with its
//! `MpiSend` (FIFO per (src, dst, tag) channel, MPI ordering semantics).
//! Shared by critical-path analysis, lateness, the inefficiency report,
//! and the timeline's arrows.
//!
//! # The channel-sharded subsystem
//!
//! MPI's non-overtaking guarantee makes every (src, dst, tag) channel
//! independently matchable: the k-th receive on a channel always pairs
//! with the k-th send on that channel, regardless of what any other
//! channel does. [`ChannelQueues`] exploits this — endpoints accumulate
//! per channel (from whole traces, row ranges, or stream shards via a
//! row offset), and pairing runs channel-by-channel. The sharded driver
//! ([`crate::exec::ops::match_messages_sharded`]) collects ranges and
//! pairs channel groups on the worker pool; the streaming driver
//! ([`crate::exec::stream`]) folds shard-local queues so stream-backed
//! sources never materialize just to match.
//!
//! Determinism: the sequential matcher consumes sends and receives in
//! global (timestamp, row) order, so each channel's queue order is the
//! (timestamp, row) order restricted to that channel. Per-channel
//! sorting by (timestamp, row) therefore reproduces the sequential
//! pairing exactly — bit-identical `send_of_recv` / `recv_of_send` —
//! and the global `sends` / `recvs` lists re-sort on the same unique
//! key. `tests/parity.rs` asserts this for every generator.

use crate::df::NULL_I64;
use crate::trace::*;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// For every row: if it is a recv instant, the row of the matching send
/// (or -1 if unmatched); if it is a send instant, the row of the matching
/// recv (or -1). All other rows -1.
#[derive(Debug, Clone, PartialEq)]
pub struct MessageMatch {
    pub send_of_recv: Vec<i64>,
    pub recv_of_send: Vec<i64>,
    /// Row indices of all send instants, in time order.
    pub sends: Vec<u32>,
    /// Row indices of all recv instants, in time order.
    pub recvs: Vec<u32>,
}

/// One channel's endpoints: (timestamp, row) pairs in insertion order.
/// Insertion happens in global row order (ranges / shards merge in row
/// order), so a stable-equivalent sort on the unique (timestamp, row)
/// key recovers MPI consumption order.
#[derive(Debug, Clone, Default)]
pub struct ChannelQueue {
    pub sends: Vec<(i64, u32)>,
    pub recvs: Vec<(i64, u32)>,
}

/// Per-(src, dst, tag) endpoint accumulator — the unit of work for
/// channel-sharded matching.
#[derive(Debug, Default)]
pub struct ChannelQueues {
    index: HashMap<(i64, i64, i64), usize>,
    queues: Vec<ChannelQueue>,
}

impl ChannelQueues {
    pub fn new() -> Self {
        Self::default()
    }

    fn queue(&mut self, key: (i64, i64, i64)) -> &mut ChannelQueue {
        let n = self.queues.len();
        let slot = *self.index.entry(key).or_insert(n);
        if slot == n {
            self.queues.push(ChannelQueue::default());
        }
        &mut self.queues[slot]
    }

    /// Scan rows `[range.0, range.1)` of `trace` for message instants and
    /// append them to the channel queues. Rows are recorded shifted by
    /// `row_offset` (stream shards pass their global base; in-memory
    /// ranges pass 0 because their indices are already global).
    pub fn collect(
        &mut self,
        trace: &Trace,
        range: (usize, usize),
        row_offset: usize,
    ) -> Result<()> {
        let ts = trace.events.i64s(COL_TS)?;
        let pr = trace.events.i64s(COL_PROC)?;
        let pa = trace.events.i64s(COL_PARTNER)?;
        let tg = trace.events.i64s(COL_TAG)?;
        let (nm, ndict) = trace.events.strs(COL_NAME)?;
        let send = ndict.code_of(SEND_EVENT);
        let recv = ndict.code_of(RECV_EVENT);
        if send.is_none() && recv.is_none() {
            return Ok(());
        }
        for i in range.0..range.1 {
            if pa[i] == NULL_I64 {
                continue;
            }
            let row = (i + row_offset) as u32;
            if Some(nm[i]) == send {
                // send's Partner = destination rank
                self.queue((pr[i], pa[i], tg[i])).sends.push((ts[i], row));
            } else if Some(nm[i]) == recv {
                // recv's Partner = source rank
                self.queue((pa[i], pr[i], tg[i])).recvs.push((ts[i], row));
            }
        }
        Ok(())
    }

    /// Append another accumulator's endpoints. Call in row order (shard
    /// order) so each channel's insertion order stays global row order.
    pub fn merge(&mut self, other: ChannelQueues) {
        let ChannelQueues { index, queues } = other;
        // index maps keys to slots; visit in slot order for determinism
        let mut keys: Vec<((i64, i64, i64), usize)> = index.into_iter().collect();
        keys.sort_unstable_by_key(|&(_, slot)| slot);
        for (key, slot) in keys {
            let src = &queues[slot];
            let dst = self.queue(key);
            dst.sends.extend_from_slice(&src.sends);
            dst.recvs.extend_from_slice(&src.recvs);
        }
    }

    /// Shift every recorded row by `offset` (stream shards collect with
    /// local rows, then shift to their global base on fold).
    pub fn shift_rows(&mut self, offset: u32) {
        if offset == 0 {
            return;
        }
        for q in &mut self.queues {
            for e in &mut q.sends {
                e.1 += offset;
            }
            for e in &mut q.recvs {
                e.1 += offset;
            }
        }
    }

    /// Approximate heap bytes of the accumulated endpoints — the
    /// streamed driver's `peak_partial_bytes` estimate (O(message
    /// endpoints), the inherent cost of end-of-stream matching).
    pub fn approx_bytes(&self) -> usize {
        let endpoints: usize = self
            .queues
            .iter()
            .map(|q| q.sends.len() + q.recvs.len())
            .sum();
        endpoints * std::mem::size_of::<(i64, u32)>()
            + self.queues.len() * std::mem::size_of::<ChannelQueue>()
    }

    pub fn num_channels(&self) -> usize {
        self.queues.len()
    }

    /// The accumulated channels (keys no longer needed — pairing is
    /// per-channel and output is row-indexed).
    pub fn into_queues(self) -> Vec<ChannelQueue> {
        self.queues
    }

    /// The accumulated channels with their (src, dst, tag) keys, in slot
    /// (= first-seen) order — what the windowed matcher folds.
    pub fn into_keyed_queues(self) -> Vec<((i64, i64, i64), ChannelQueue)> {
        let ChannelQueues { index, queues } = self;
        let mut keys: Vec<((i64, i64, i64), usize)> = index.into_iter().collect();
        keys.sort_unstable_by_key(|&(_, slot)| slot);
        keys.into_iter()
            .zip(queues)
            .map(|((key, _), q)| (key, q))
            .collect()
    }

    /// FIFO-pair every channel sequentially and assemble the
    /// [`MessageMatch`] for a trace of `total_rows` rows. The sharded
    /// driver uses [`pair_channel`] + [`assemble_match`] directly to run
    /// the pairing on the worker pool.
    pub fn finish(self, total_rows: usize) -> MessageMatch {
        let mut paired = PairedChannels::default();
        for mut q in self.queues {
            let pairs = pair_channel(&mut q);
            paired.absorb(pairs, q);
        }
        assemble_match(paired, total_rows)
    }
}

/// Matched pairs plus every endpoint of a group of channels — what one
/// pairing task returns.
#[derive(Debug, Default)]
pub struct PairedChannels {
    /// (send row, recv row) matched pairs.
    pub pairs: Vec<(u32, u32)>,
    /// All send endpoints (ts, row), matched or not.
    pub sends: Vec<(i64, u32)>,
    /// All recv endpoints (ts, row), matched or not.
    pub recvs: Vec<(i64, u32)>,
}

impl PairedChannels {
    /// Fold one paired channel into the group result.
    pub fn absorb(&mut self, pairs: Vec<(u32, u32)>, q: ChannelQueue) {
        self.pairs.extend(pairs);
        self.sends.extend(q.sends);
        self.recvs.extend(q.recvs);
    }
}

/// Sort one channel's endpoints into MPI consumption order — the unique
/// (timestamp, row) key, equal to the sequential matcher's stable
/// timestamp sort over row-ordered input — and FIFO-pair the k-th send
/// with the k-th recv. Trailing unmatched endpoints stay unpaired.
pub fn pair_channel(q: &mut ChannelQueue) -> Vec<(u32, u32)> {
    q.sends.sort_unstable();
    q.recvs.sort_unstable();
    q.sends
        .iter()
        .zip(q.recvs.iter())
        .map(|(&(_, s), &(_, r))| (s, r))
        .collect()
}

/// Assemble the row-indexed match arrays and the global time-ordered
/// endpoint lists from paired channel groups.
pub fn assemble_match(paired: PairedChannels, total_rows: usize) -> MessageMatch {
    let PairedChannels { pairs, mut sends, mut recvs } = paired;
    let mut send_of_recv = vec![-1i64; total_rows];
    let mut recv_of_send = vec![-1i64; total_rows];
    for (s, r) in pairs {
        send_of_recv[r as usize] = s as i64;
        recv_of_send[s as usize] = r as i64;
    }
    // (ts, row) keys are unique, so the unstable sort is deterministic
    // and equals the sequential stable-by-ts order over row-ordered input.
    sends.sort_unstable();
    recvs.sort_unstable();
    MessageMatch {
        send_of_recv,
        recv_of_send,
        sends: sends.into_iter().map(|(_, r)| r).collect(),
        recvs: recvs.into_iter().map(|(_, r)| r).collect(),
    }
}

/// Match sends to recvs. Sends and recvs are consumed in timestamp order
/// per (src, dst, tag) channel, which is MPI's non-overtaking guarantee.
/// This is the sequential reference; the channel-sharded equivalent is
/// [`crate::exec::ops::match_messages_sharded`] (bit-identical, see
/// `tests/parity.rs`).
pub fn match_messages(trace: &Trace) -> Result<MessageMatch> {
    let mut acc = ChannelQueues::new();
    acc.collect(trace, (0, trace.len()), 0)?;
    Ok(acc.finish(trace.len()))
}

// -- windowed pair-and-drain matching ---------------------------------------

/// Streaming matcher driven by the pre-scan channel census: per-channel
/// queues accumulate endpoints as shards fold, and a channel is paired
/// and **drained the moment the census says it has no endpoints left
/// downstream** (its accumulated counts equal the census totals).
/// Matcher residency is therefore bounded by the open-channel window —
/// the channels whose src or dst block has not finished streaming —
/// instead of O(all message endpoints), while the pairing per channel is
/// the same unique-(timestamp, row) sort + FIFO zip as [`pair_channel`],
/// so the row-indexed output is bit-identical to the sequential matcher.
///
/// A census that disagrees with the stream cannot make this silently
/// wrong: channels the census never mentions, or whose counts are never
/// reached, simply stay open until [`WindowedMatcher::finish`] (the
/// result degrades to end-of-stream pairing for those channels), and a
/// census that provably lied — endpoints arriving for a channel it said
/// was complete, the one shape that could mis-pair — is a deterministic
/// [`WindowedMatcher::fold`] error, exactly like any other corrupt-data
/// read. (For the archive formats the census travels with, a checksum
/// already rejects damaged censuses before they get here.)
#[derive(Debug, Default)]
pub struct WindowedMatcher {
    /// channel → census (send, recv) totals.
    expected: std::collections::HashMap<(i64, i64, i64), (u64, u64)>,
    /// open channels, insertion-ordered (slot order) for a deterministic
    /// final drain; a drained channel keeps its slot as `None`.
    index: std::collections::HashMap<(i64, i64, i64), usize>,
    open: Vec<Option<ChannelQueue>>,
    /// row-indexed match arrays, grown as the stream advances.
    send_of_recv: Vec<i64>,
    recv_of_send: Vec<i64>,
    /// drained endpoints, kept only when the caller needs the global
    /// time-ordered lists (full [`MessageMatch`] output).
    keep_endpoints: bool,
    sends: Vec<(i64, u32)>,
    recvs: Vec<(i64, u32)>,
    /// matched (send row, recv row) pairs drained since the last
    /// [`WindowedMatcher::take_drained_pairs`] call — buffered only when
    /// enabled, so residency stays bounded for callers that never
    /// consume them.
    collect_pairs: bool,
    drained_pairs: Vec<(u32, u32)>,
}

impl WindowedMatcher {
    /// `expected` is the census channel map ((src, dst, tag) → endpoint
    /// totals); `keep_endpoints` retains drained endpoints for the full
    /// [`MessageMatch`] (the row arrays alone need no endpoint storage).
    pub fn new(
        expected: std::collections::HashMap<(i64, i64, i64), (u64, u64)>,
        keep_endpoints: bool,
    ) -> Self {
        WindowedMatcher { expected, keep_endpoints, ..Default::default() }
    }

    /// Buffer matched pairs as channels drain so the caller can overlap
    /// downstream work mid-ingest (the streamed critical-path walk
    /// builds its exit tables from these while the stream is still
    /// folding). Off by default: disabled, drained pairs are dropped.
    pub fn collect_drained_pairs(&mut self, on: bool) {
        self.collect_pairs = on;
    }

    /// Take the pairs drained since the last call. Empty unless
    /// [`WindowedMatcher::collect_drained_pairs`] enabled buffering;
    /// take them before [`WindowedMatcher::finish_with_pairs`], which
    /// resets the buffer to report only its own final drains.
    pub fn take_drained_pairs(&mut self) -> Vec<(u32, u32)> {
        std::mem::take(&mut self.drained_pairs)
    }

    /// Fold one shard's channel queues (rows already shifted to their
    /// global base). `total_rows` is the stream's row count so far —
    /// every endpoint recorded up to now lies below it. Errors when an
    /// endpoint arrives for a channel the census declared complete (a
    /// census that disagrees with the stream could otherwise mis-pair).
    pub fn fold(&mut self, q: ChannelQueues, total_rows: usize) -> Result<()> {
        self.send_of_recv.resize(total_rows, -1);
        self.recv_of_send.resize(total_rows, -1);
        for (key, part) in q.into_keyed_queues() {
            let n = self.open.len();
            let slot = *self.index.entry(key).or_insert(n);
            if slot == n {
                self.open.push(Some(ChannelQueue::default()));
            }
            let Some(dst) = self.open[slot].as_mut() else {
                // the channel already drained at its census totals, yet
                // more endpoints exist: the census lied in the one way
                // that could silently mis-pair, so refuse the stream
                bail!(
                    "channel census disagrees with the stream: endpoints for \
                     channel ({}, {}, {}) arrived after its census said it \
                     was complete",
                    key.0,
                    key.1,
                    key.2
                );
            };
            dst.sends.extend_from_slice(&part.sends);
            dst.recvs.extend_from_slice(&part.recvs);
            if let Some(&(es, er)) = self.expected.get(&key) {
                let complete =
                    dst.sends.len() as u64 == es && dst.recvs.len() as u64 == er;
                if complete {
                    let q = self.open[slot].take().unwrap_or_default();
                    self.drain(q);
                }
            }
        }
        Ok(())
    }

    /// Pair one complete channel and retire its queue into the outputs.
    fn drain(&mut self, mut q: ChannelQueue) {
        let pairs = pair_channel(&mut q);
        for &(s, r) in &pairs {
            self.send_of_recv[r as usize] = s as i64;
            self.recv_of_send[s as usize] = r as i64;
        }
        if self.collect_pairs {
            self.drained_pairs.extend(pairs);
        }
        if self.keep_endpoints {
            self.sends.extend(q.sends);
            self.recvs.extend(q.recvs);
        }
    }

    /// Bytes currently held in open channel queues — the matcher's
    /// actual partial state (the row arrays are output-sized).
    pub fn queue_bytes(&self) -> usize {
        let endpoints: usize = self
            .open
            .iter()
            .flatten()
            .map(|q| q.sends.len() + q.recvs.len())
            .sum();
        endpoints * std::mem::size_of::<(i64, u32)>()
            + self.open.len() * std::mem::size_of::<Option<ChannelQueue>>()
    }

    /// End of stream: drain every still-open channel (in first-seen
    /// order) and assemble the match for `total_rows` rows.
    pub fn finish(self, total_rows: usize) -> MessageMatch {
        self.finish_with_pairs(total_rows).0
    }

    /// [`WindowedMatcher::finish`], additionally returning the matched
    /// pairs drained *by this call* — the channels that never completed
    /// mid-stream. Together with the pairs taken during ingest this is
    /// the complete pair set, which is how the streamed critical-path
    /// walk finishes its exit tables without rescanning the match.
    pub fn finish_with_pairs(mut self, total_rows: usize) -> (MessageMatch, Vec<(u32, u32)>) {
        self.send_of_recv.resize(total_rows, -1);
        self.recv_of_send.resize(total_rows, -1);
        self.collect_pairs = true;
        self.drained_pairs = Vec::new();
        let open = std::mem::take(&mut self.open);
        for q in open.into_iter().flatten() {
            self.drain(q);
        }
        let WindowedMatcher {
            send_of_recv, recv_of_send, mut sends, mut recvs, drained_pairs, ..
        } = self;
        // (ts, row) keys are unique: the unstable sort reproduces the
        // sequential global time order exactly (see `assemble_match`)
        sends.sort_unstable();
        recvs.sort_unstable();
        let m = MessageMatch {
            send_of_recv,
            recv_of_send,
            sends: sends.into_iter().map(|(_, r)| r).collect(),
            recvs: recvs.into_iter().map(|(_, r)| r).collect(),
        };
        (m, drained_pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_matching_per_channel() {
        let mut b = TraceBuilder::new();
        // two sends 0->1 tag 0, in order; one send 0->1 tag 7
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.send(0, 0, 30, 1, 300, 7);
        b.recv(1, 0, 40, 0, 100, 0);
        b.recv(1, 0, 50, 0, 200, 0);
        b.recv(1, 0, 60, 0, 300, 7);
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        let ts = t.timestamps().unwrap();
        // recv at 40 matches send at 10, recv at 50 matches send at 20
        for (&r, want_send_ts) in m.recvs.iter().zip([10i64, 20, 60].iter()) {
            let s = m.send_of_recv[r as usize];
            if ts[r as usize] == 60 {
                assert_eq!(ts[s as usize], 30); // tag 7 channel
            } else {
                assert!(*want_send_ts == ts[s as usize] || ts[s as usize] == 20);
            }
        }
        // bijectivity
        for &s in &m.sends {
            let r = m.recv_of_send[s as usize];
            assert!(r >= 0);
            assert_eq!(m.send_of_recv[r as usize], s as i64);
        }
    }

    #[test]
    fn unmatched_recv_stays_negative() {
        let mut b = TraceBuilder::new();
        b.recv(1, 0, 40, 0, 100, 0); // no send anywhere
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        assert_eq!(m.send_of_recv[0], -1);
    }

    #[test]
    fn unmatched_sends_stay_negative_and_listed() {
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.recv(1, 0, 40, 0, 100, 0); // only the first send is consumed
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        assert_eq!(m.sends.len(), 2);
        assert_eq!(m.recvs.len(), 1);
        let matched = m.recv_of_send.iter().filter(|&&r| r >= 0).count();
        assert_eq!(matched, 1);
        // the FIFO head (ts 10) is the one that matched
        let r = m.recvs[0] as usize;
        let s = m.send_of_recv[r] as usize;
        assert_eq!(t.timestamps().unwrap()[s], 10);
    }

    #[test]
    fn zero_message_trace_matches_nothing() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.leave(0, 0, 10, "main");
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        assert!(m.sends.is_empty() && m.recvs.is_empty());
        assert!(m.send_of_recv.iter().all(|&v| v == -1));
    }

    #[test]
    fn duplicate_timestamp_sends_pair_in_row_order() {
        // Two sends on one channel with the same timestamp: the earlier
        // row is the FIFO head (the (ts, row) key is unique).
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 111, 0); // row order decides
        b.send(0, 0, 10, 1, 222, 0);
        b.recv(1, 0, 40, 0, 111, 0);
        b.recv(1, 0, 50, 0, 222, 0);
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        let first_recv = m.recvs[0] as usize;
        let s = m.send_of_recv[first_recv] as usize;
        assert_eq!(s as u32, m.sends[0], "first recv pairs with first-row send");
        // and the pairing is a bijection over both sends
        assert!(m.recv_of_send.iter().filter(|&&r| r >= 0).count() == 2);
    }

    #[test]
    fn collect_with_offset_shifts_rows() {
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 100, 0);
        let t = b.finish();
        let mut acc = ChannelQueues::new();
        acc.collect(&t, (0, t.len()), 5).unwrap();
        let qs = acc.into_queues();
        assert_eq!(qs.len(), 1);
        assert_eq!(qs[0].sends, vec![(10, 5)]);
    }

    /// Shard-by-shard windowed matching with a census must equal the
    /// sequential matcher bit-for-bit while draining complete channels
    /// before end of stream.
    #[test]
    fn windowed_matcher_matches_sequential_and_drains_early() {
        let mut b = TraceBuilder::new();
        // proc 0: sends to 1 (two messages, one channel)
        b.enter(0, 0, 0, "main");
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.leave(0, 0, 90, "main");
        // proc 1: receives both, sends one to 2
        b.enter(1, 0, 0, "main");
        b.recv(1, 0, 30, 0, 100, 0);
        b.recv(1, 0, 40, 0, 200, 0);
        b.send(1, 0, 50, 2, 300, 7);
        b.leave(1, 0, 90, "main");
        // proc 2: receives from 1, plus an unmatched recv from 3
        b.enter(2, 0, 0, "main");
        b.recv(2, 0, 60, 1, 300, 7);
        b.recv(2, 0, 70, 3, 1, 0);
        b.leave(2, 0, 90, "main");
        let t = b.finish();
        let seq = match_messages(&t).unwrap();

        // the census the pre-scan would produce
        let mut expected = std::collections::HashMap::new();
        expected.insert((0i64, 1i64, 0i64), (2u64, 2u64));
        expected.insert((1, 2, 7), (1, 1));
        expected.insert((3, 2, 0), (0, 1));

        // stream one process block at a time
        let pr = t.processes().unwrap().to_vec();
        let mut m = WindowedMatcher::new(expected, true);
        let mut start = 0usize;
        for p in 0..3i64 {
            let end = start + pr.iter().filter(|&&x| x == p).count();
            let mut q = ChannelQueues::new();
            q.collect(&t, (start, end), 0).unwrap();
            m.fold(q, end).unwrap();
            if p == 0 {
                // channel (0,1,0) is still waiting for its receives
                assert!(m.queue_bytes() > 0, "open channel must be resident");
            }
            if p == 1 {
                // channel (0,1,0) reached its census totals at block 1:
                // it must be paired and drained before the stream ends
                let slot = m.index[&(0i64, 1i64, 0i64)];
                assert!(m.open[slot].is_none(), "complete channel not drained");
            }
            start = end;
        }
        let win = m.finish(t.len());
        assert_eq!(win, seq, "windowed pairing must equal sequential");
    }

    /// Channels that reach their census totals mid-stream must surface
    /// their matched pairs through the drain hook before end of stream,
    /// and `finish_with_pairs` must deliver exactly the stragglers — the
    /// union is the full sequential pair set.
    #[test]
    fn windowed_matcher_exposes_drained_pairs() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.leave(0, 0, 90, "main");
        b.enter(1, 0, 0, "main");
        b.recv(1, 0, 30, 0, 100, 0);
        b.recv(1, 0, 40, 0, 200, 0);
        b.send(1, 0, 50, 2, 300, 7);
        b.leave(1, 0, 90, "main");
        b.enter(2, 0, 0, "main");
        b.recv(2, 0, 60, 1, 300, 7);
        b.leave(2, 0, 90, "main");
        let t = b.finish();
        let seq = match_messages(&t).unwrap();

        let mut expected = std::collections::HashMap::new();
        expected.insert((0i64, 1i64, 0i64), (2u64, 2u64));
        // channel (1, 2, 7) is deliberately missing from the census: it
        // stays open until finish and must arrive via the final pairs
        let pr = t.processes().unwrap().to_vec();
        let mut m = WindowedMatcher::new(expected, false);
        m.collect_drained_pairs(true);
        let mut early: Vec<(u32, u32)> = Vec::new();
        let mut start = 0usize;
        for p in 0..3i64 {
            let end = start + pr.iter().filter(|&&x| x == p).count();
            let mut q = ChannelQueues::new();
            q.collect(&t, (start, end), 0).unwrap();
            m.fold(q, end).unwrap();
            early.extend(m.take_drained_pairs());
            start = end;
        }
        assert!(!early.is_empty(), "complete channels must surface pairs mid-stream");
        let (win, late) = m.finish_with_pairs(t.len());
        assert!(!late.is_empty(), "uncensused channel must drain at finish");
        assert_eq!(win.send_of_recv, seq.send_of_recv);
        assert_eq!(win.recv_of_send, seq.recv_of_send);
        let mut all: Vec<(u32, u32)> = early.into_iter().chain(late).collect();
        all.sort_unstable();
        let mut want: Vec<(u32, u32)> = seq
            .send_of_recv
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s >= 0)
            .map(|(r, &s)| (s as u32, r as u32))
            .collect();
        want.sort_unstable();
        assert_eq!(all, want, "early + final pairs must be the whole match");
    }

    /// A census that undercounts a channel must degrade to end-of-stream
    /// pairing for the stragglers — never panic or mis-pair rows.
    #[test]
    fn windowed_matcher_survives_lying_census() {
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.recv(1, 0, 30, 0, 100, 0);
        b.recv(1, 0, 40, 0, 200, 0);
        let t = b.finish();
        // census claims one send/one recv: the counts blow straight past
        // the claimed totals without ever equaling them, so the channel
        // stays open and pairs at finish — full, correct pairing
        let mut expected = std::collections::HashMap::new();
        expected.insert((0i64, 1i64, 0i64), (1u64, 1u64));
        let mut m = WindowedMatcher::new(expected, true);
        for row in 0..t.len() {
            let mut q = ChannelQueues::new();
            q.collect(&t, (row, row + 1), 0).unwrap();
            m.fold(q, row + 1).unwrap();
        }
        let win = m.finish(t.len());
        // every endpoint is still listed and the pairing is a bijection
        assert_eq!(win.sends.len(), 2);
        assert_eq!(win.recvs.len(), 2);
        let matched = win.recv_of_send.iter().filter(|&&r| r >= 0).count();
        assert_eq!(matched, 2);
    }

    /// A census whose counts are transiently *equal* to the accumulated
    /// endpoints triggers a drain; if more endpoints then arrive, the
    /// matcher must error deterministically — the one lying-census shape
    /// that could silently mis-pair is refused instead.
    #[test]
    fn windowed_matcher_rejects_census_contradicted_by_the_stream() {
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.recv(1, 0, 30, 0, 100, 0);
        b.recv(1, 0, 40, 0, 200, 0);
        let t = b.finish();
        // census claims (2, 1): equality holds after the first recv, the
        // channel drains, and the second recv then contradicts it
        let mut expected = std::collections::HashMap::new();
        expected.insert((0i64, 1i64, 0i64), (2u64, 1u64));
        let mut m = WindowedMatcher::new(expected, true);
        let mut err = None;
        for row in 0..t.len() {
            let mut q = ChannelQueues::new();
            q.collect(&t, (row, row + 1), 0).unwrap();
            if let Err(e) = m.fold(q, row + 1) {
                err = Some(e);
                break;
            }
        }
        let err = err.expect("the contradicted census must be refused");
        assert!(err.to_string().contains("census disagrees"), "{err}");
    }

    #[test]
    fn merge_preserves_row_order_per_channel() {
        let mut b = TraceBuilder::new();
        b.send(0, 0, 10, 1, 100, 0);
        let t0 = b.finish();
        let mut b = TraceBuilder::new();
        b.send(0, 0, 20, 1, 100, 0);
        let t1 = b.finish();
        let mut a = ChannelQueues::new();
        a.collect(&t0, (0, 1), 0).unwrap();
        let mut p = ChannelQueues::new();
        p.collect(&t1, (0, 1), 1).unwrap();
        a.merge(p);
        let qs = a.into_queues();
        assert_eq!(qs[0].sends, vec![(10, 0), (20, 1)]);
    }
}
