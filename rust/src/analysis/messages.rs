//! Point-to-point message matching: pair each `MpiRecv` instant with its
//! `MpiSend` (FIFO per (src, dst, tag) channel, MPI ordering semantics).
//! Shared by critical-path analysis, lateness, and the timeline's arrows.

use crate::df::NULL_I64;
use crate::trace::*;
use anyhow::Result;
use std::collections::HashMap;

/// For every row: if it is a recv instant, the row of the matching send
/// (or -1 if unmatched); if it is a send instant, the row of the matching
/// recv (or -1). All other rows -1.
#[derive(Debug, Clone)]
pub struct MessageMatch {
    pub send_of_recv: Vec<i64>,
    pub recv_of_send: Vec<i64>,
    /// Row indices of all send instants, in time order.
    pub sends: Vec<u32>,
    /// Row indices of all recv instants, in time order.
    pub recvs: Vec<u32>,
}

/// Match sends to recvs. Sends and recvs are consumed in timestamp order
/// per (src, dst, tag) channel, which is MPI's non-overtaking guarantee.
pub fn match_messages(trace: &Trace) -> Result<MessageMatch> {
    let n = trace.len();
    let ts = trace.events.i64s(COL_TS)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let tg = trace.events.i64s(COL_TAG)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let send = ndict.code_of(SEND_EVENT);
    let recv = ndict.code_of(RECV_EVENT);

    let mut sends: Vec<u32> = (0..n as u32)
        .filter(|&i| Some(nm[i as usize]) == send && pa[i as usize] != NULL_I64)
        .collect();
    let mut recvs: Vec<u32> = (0..n as u32)
        .filter(|&i| Some(nm[i as usize]) == recv && pa[i as usize] != NULL_I64)
        .collect();
    sends.sort_by_key(|&i| ts[i as usize]);
    recvs.sort_by_key(|&i| ts[i as usize]);

    // FIFO queues per channel (src, dst, tag)
    let mut queues: HashMap<(i64, i64, i64), std::collections::VecDeque<u32>> =
        HashMap::new();
    for &s in &sends {
        let i = s as usize;
        queues
            .entry((pr[i], pa[i], tg[i]))
            .or_default()
            .push_back(s);
    }
    let mut send_of_recv = vec![-1i64; n];
    let mut recv_of_send = vec![-1i64; n];
    for &r in &recvs {
        let i = r as usize;
        // recv's Partner = source rank
        if let Some(q) = queues.get_mut(&(pa[i], pr[i], tg[i])) {
            if let Some(s) = q.pop_front() {
                send_of_recv[i] = s as i64;
                recv_of_send[s as usize] = r as i64;
            }
        }
    }
    Ok(MessageMatch { send_of_recv, recv_of_send, sends, recvs })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_matching_per_channel() {
        let mut b = TraceBuilder::new();
        // two sends 0->1 tag 0, in order; one send 0->1 tag 7
        b.send(0, 0, 10, 1, 100, 0);
        b.send(0, 0, 20, 1, 200, 0);
        b.send(0, 0, 30, 1, 300, 7);
        b.recv(1, 0, 40, 0, 100, 0);
        b.recv(1, 0, 50, 0, 200, 0);
        b.recv(1, 0, 60, 0, 300, 7);
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        let ts = t.timestamps().unwrap();
        // recv at 40 matches send at 10, recv at 50 matches send at 20
        for (&r, want_send_ts) in m.recvs.iter().zip([10i64, 20, 60].iter()) {
            let s = m.send_of_recv[r as usize];
            if ts[r as usize] == 60 {
                assert_eq!(ts[s as usize], 30); // tag 7 channel
            } else {
                assert!(*want_send_ts == ts[s as usize] || ts[s as usize] == 20);
            }
        }
        // bijectivity
        for &s in &m.sends {
            let r = m.recv_of_send[s as usize];
            assert!(r >= 0);
            assert_eq!(m.send_of_recv[r as usize], s as i64);
        }
    }

    #[test]
    fn unmatched_recv_stays_negative() {
        let mut b = TraceBuilder::new();
        b.recv(1, 0, 40, 0, 100, 0); // no send anywhere
        let t = b.finish();
        let m = match_messages(&t).unwrap();
        assert_eq!(m.send_of_recv[0], -1);
    }
}
