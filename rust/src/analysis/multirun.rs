//! `multi_run_analysis` (paper §IV.D, Fig. 12): compare flat profiles
//! across traces from multiple executions (scaling studies, variants).

use super::flat_profile::{flat_profile, Metric};
use crate::trace::*;
use anyhow::Result;
use std::collections::HashMap;

/// Cross-run comparison table: `values[run][func]`.
#[derive(Debug, Clone)]
pub struct MultiRun {
    /// One label per run (process count, or the trace's app/source name).
    pub run_labels: Vec<String>,
    pub func_names: Vec<String>,
    pub values: Vec<Vec<f64>>,
    pub metric: Metric,
}

impl MultiRun {
    /// Render as an aligned text table (the Fig. 12 dataframe).
    pub fn show(&self) -> String {
        let mut out = String::new();
        let w = 16usize;
        out.push_str(&format!("{:>12}  ", "run"));
        for f in &self.func_names {
            let name = if f.len() > w { &f[..w] } else { f };
            out.push_str(&format!("{name:>w$}  "));
        }
        out.push('\n');
        for (l, row) in self.run_labels.iter().zip(&self.values) {
            out.push_str(&format!("{l:>12}  "));
            for v in row {
                out.push_str(&format!("{v:>w$.3e}  "));
            }
            out.push('\n');
        }
        out
    }

    /// values[run][func] / #processes of that run — per-process view.
    pub fn per_process(&self, procs: &[usize]) -> Vec<Vec<f64>> {
        self.values
            .iter()
            .zip(procs)
            .map(|(row, &p)| row.iter().map(|v| v / p.max(1) as f64).collect())
            .collect()
    }
}

/// Compute flat profiles for every trace and align them on the union of
/// the `top_k` functions of each run (ranked by the chosen metric).
/// Run labels default to the process count (the Fig. 12 x-axis).
pub fn multi_run_analysis(
    traces: &mut [Trace],
    metric: Metric,
    top_k: usize,
) -> Result<MultiRun> {
    let mut profiles = Vec::with_capacity(traces.len());
    let mut labels = Vec::with_capacity(traces.len());
    for t in traces.iter_mut() {
        profiles.push(flat_profile(t, metric)?);
        labels.push(t.num_processes()?.to_string());
    }
    Ok(align_profiles(profiles, labels, metric, top_k))
}

/// Align per-run flat profiles on the union of each run's `top_k`
/// functions — the deterministic reduction shared by
/// [`multi_run_analysis`] and the batch entry point
/// (`AnalysisSession::run_batch`), so batch results are identical to
/// per-trace sequential runs. Functions enter the union in (run order,
/// rank order) and the final sort is stable, so ties resolve the same
/// way every time.
pub(crate) fn align_profiles(
    profiles: Vec<Vec<super::flat_profile::ProfileRow>>,
    labels: Vec<String>,
    metric: Metric,
    top_k: usize,
) -> MultiRun {
    // union of each run's top-k functions in first-seen order, ranked by
    // total across runs
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut funcs: Vec<(String, f64)> = Vec::new();
    for p in &profiles {
        for row in p.iter().take(top_k) {
            match index.get(row.name.as_str()) {
                Some(&slot) => funcs[slot].1 += row.value,
                None => {
                    index.insert(row.name.clone(), funcs.len());
                    funcs.push((row.name.clone(), row.value));
                }
            }
        }
    }
    funcs.sort_by(|a, b| b.1.total_cmp(&a.1));
    let func_names: Vec<String> = funcs.into_iter().map(|(n, _)| n).collect();

    let values = profiles
        .iter()
        .map(|p| {
            let by_name: HashMap<&str, f64> =
                p.iter().map(|r| (r.name.as_str(), r.value)).collect();
            func_names
                .iter()
                .map(|f| by_name.get(f.as_str()).copied().unwrap_or(0.0))
                .collect()
        })
        .collect();
    MultiRun { run_labels: labels, func_names, values, metric }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(nprocs: i64, work_ns: i64) -> Trace {
        let mut b = TraceBuilder::new();
        for p in 0..nprocs {
            b.enter(p, 0, 0, "main");
            b.enter(p, 0, 10, "computeRhs");
            b.leave(p, 0, 10 + work_ns, "computeRhs");
            b.enter(p, 0, 20 + work_ns, "gradC2C");
            b.leave(p, 0, 20 + work_ns * 2, "gradC2C");
            b.leave(p, 0, 40 + work_ns * 2, "main");
        }
        b.finish()
    }

    #[test]
    fn aligns_runs_on_common_functions() {
        let mut traces = vec![run(2, 100), run(4, 120), run(8, 150)];
        let mr = multi_run_analysis(&mut traces, Metric::ExcTime, 5).unwrap();
        assert_eq!(mr.run_labels, vec!["2", "4", "8"]);
        assert!(mr.func_names.contains(&"computeRhs".to_string()));
        let idx = mr.func_names.iter().position(|f| f == "computeRhs").unwrap();
        assert_eq!(mr.values[0][idx], 200.0); // 2 procs x 100
        assert_eq!(mr.values[2][idx], 1200.0); // 8 procs x 150
    }

    #[test]
    fn missing_function_reports_zero() {
        let mut a = run(2, 100);
        let mut bldr = TraceBuilder::new();
        bldr.enter(0, 0, 0, "onlyhere");
        bldr.leave(0, 0, 50, "onlyhere");
        let mut b = bldr.finish();
        let mut traces = vec![std::mem::take(&mut a), std::mem::take(&mut b)];
        let mr = multi_run_analysis(&mut traces, Metric::ExcTime, 5).unwrap();
        let idx = mr.func_names.iter().position(|f| f == "onlyhere").unwrap();
        assert_eq!(mr.values[0][idx], 0.0);
        assert_eq!(mr.values[1][idx], 50.0);
    }

    #[test]
    fn show_renders_table() {
        let mut traces = vec![run(2, 100), run(4, 100)];
        let mr = multi_run_analysis(&mut traces, Metric::ExcTime, 3).unwrap();
        let s = mr.show();
        assert!(s.contains("computeRhs"));
        assert!(s.contains('2') && s.contains('4'));
    }
}
