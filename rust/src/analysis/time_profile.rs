//! `time_profile` (paper §IV.B, Fig. 2): exclusive time per function per
//! time bin, summed across all processes/threads — "a flat profile over
//! time".
//!
//! The pure-Rust engines all share one three-stage core — segment
//! extraction ([`exclusive_segments`]), function census + ranking, and
//! direct per-*series* binning (`bin_segments_series`): every segment
//! adds its fractional bin overlaps straight into its ranked output
//! series, with non-top functions adding into `"other"` — so the
//! sequential path, the bin-axis-sharded path
//! (`crate::exec::ops::time_profile`), the streamed two-pass fold and
//! the census-backed streamed fold (`crate::exec::stream`) are
//! **bit-identical** by construction: every (series, bin) cell —
//! including `"other"`, which interleaves its member functions'
//! contributions — accumulates in global segment order on all of them.
//! Binning directly into series keeps partial state O(series × bins)
//! everywhere: with a top-k ranking the memory no longer scales with
//! the number of distinct function names (the earlier design kept
//! O(all-functions × bins) slot rows and collapsed at the end, which
//! was pathological for name-rich traces). The PJRT path in
//! [`crate::runtime::ops`] (the AOT Pallas `time_hist` kernel) is
//! validated against this implementation within numeric tolerance in
//! integration tests.
//!
//! Both consume the same [`exclusive_segments`] extraction, which converts
//! matched Enter/Leave pairs into *exclusive* intervals (the gaps where a
//! call is on top of the stack), so a function's own time never
//! double-counts its children's.


use crate::trace::*;
use anyhow::{bail, Result};

/// Result of a time profile: `values[bin][func]` = ns of exclusive time.
#[derive(Debug, Clone, PartialEq)]
pub struct TimeProfile {
    pub bin_edges: Vec<i64>,
    pub func_names: Vec<String>,
    pub values: Vec<Vec<f64>>,
}

impl TimeProfile {
    pub fn num_bins(&self) -> usize {
        self.values.len()
    }

    /// Total busy time accumulated over all bins and functions.
    pub fn total(&self) -> f64 {
        self.values.iter().flatten().sum()
    }

    /// Index of `name` in `func_names`.
    pub fn func_index(&self, name: &str) -> Option<usize> {
        self.func_names.iter().position(|n| n == name)
    }

    /// Per-bin total across functions (the "utilization" series used by
    /// pattern detection).
    pub fn bin_totals(&self) -> Vec<f64> {
        self.values.iter().map(|row| row.iter().sum()).collect()
    }
}

/// An exclusive-time segment of one function invocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub start: i64,
    pub end: i64,
    /// Index into the name dictionary of the events table.
    pub name_code: u32,
    pub proc: i64,
}

/// Extract exclusive segments: for each matched call, the sub-intervals of
/// [enter, leave) during which no child is executing.
pub fn exclusive_segments(trace: &mut Trace) -> Result<Vec<Segment>> {
    super::match_caller_callee::prepare(trace)?;
    let n = trace.len();
    let ts = trace.events.i64s(COL_TS)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let th = trace.events.i64s(COL_THREAD)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, _) = trace.events.strs(COL_NAME)?;
    let enter = edict.code_of(ENTER);
    let leave = edict.code_of(LEAVE);

    // Walk each (proc, thread) stream: on Enter push; the segment of the
    // parent that was running is cut at this point. On Leave, the finished
    // call contributes its tail segment and the parent resumes.
    let mut segs = Vec::with_capacity(n / 2);
    // contiguous (proc, thread) runs: cache the current stream's stack
    let mut stacks: Vec<Vec<(u32, i64)>> = Vec::new();
    let mut stream_of: std::collections::HashMap<(i64, i64), usize> =
        std::collections::HashMap::new();
    let mut cur_key = (i64::MIN, i64::MIN);
    let mut cur = usize::MAX;
    for i in 0..n {
        let code = Some(et[i]);
        if (pr[i], th[i]) != cur_key {
            cur_key = (pr[i], th[i]);
            cur = *stream_of.entry(cur_key).or_insert_with(|| {
                stacks.push(Vec::new());
                stacks.len() - 1
            });
        }
        let stack = &mut stacks[cur];
        if code == enter {
            // Unmatched enters (truncated/filtered traces) still push: their
            // children pair up normally; only the unmatched call's own tail
            // segment is lost, which is exactly the data the filter cut.
            if let Some(&mut (pname, ref mut pstart)) = stack.last_mut() {
                if ts[i] > *pstart {
                    segs.push(Segment {
                        start: *pstart,
                        end: ts[i],
                        name_code: pname,
                        proc: pr[i],
                    });
                }
                *pstart = ts[i]; // will be re-cut when child leaves
            }
            stack.push((nm[i], ts[i]));
        } else if code == leave {
            if let Some((cname, cstart)) = stack.pop() {
                if ts[i] > cstart {
                    segs.push(Segment {
                        start: cstart,
                        end: ts[i],
                        name_code: cname,
                        proc: pr[i],
                    });
                }
                if let Some(&mut (_, ref mut pstart)) = stack.last_mut() {
                    *pstart = ts[i]; // parent resumes here
                }
            }
        }
    }
    Ok(segs)
}

/// First-seen function census over segments — stage 2a, shared by every
/// engine. Slots are assigned in order of first segment occurrence;
/// totals are exclusive-ns sums (integer-valued f64, so cross-shard
/// folds are exact in any grouping, and the streamed driver can grow one
/// census incrementally per shard while reproducing the same slot
/// order).
#[derive(Default)]
pub(crate) struct FuncCensus {
    pub(crate) slot_of_code: std::collections::HashMap<u32, usize>,
    /// slot → name code, in first-seen order.
    pub(crate) codes: Vec<u32>,
    /// slot → total exclusive ns.
    pub(crate) totals: Vec<f64>,
}

impl FuncCensus {
    /// Slot of `code`, assigning the next slot on first sight.
    pub(crate) fn slot(&mut self, code: u32) -> usize {
        match self.slot_of_code.get(&code) {
            Some(&s) => s,
            None => {
                let s = self.codes.len();
                self.slot_of_code.insert(code, s);
                self.codes.push(code);
                self.totals.push(0.0);
                s
            }
        }
    }

    /// Account one segment's duration to its function.
    pub(crate) fn add(&mut self, code: u32, dur: f64) {
        let s = self.slot(code);
        self.totals[s] += dur;
    }
}

/// Census over a complete segment list (the eager engines' stage 2a).
pub(crate) fn census(segs: &[Segment]) -> FuncCensus {
    let mut c = FuncCensus::default();
    for s in segs {
        c.add(s.name_code, (s.end - s.start) as f64);
    }
    c
}

/// Which name-dictionary code maps to which output series, plus the
/// ordered series names — stage 2b of the profile, shared verbatim by
/// the sequential path, [`crate::exec::ops::time_profile`], and the
/// streamed driver so all rank functions identically (ties resolve by
/// first-seen segment order via the stable sort, not hash-map iteration
/// order).
pub(crate) struct SeriesSpec {
    pub(crate) func_of_code: std::collections::HashMap<u32, usize>,
    pub(crate) func_names: Vec<String>,
    pub(crate) other_slot: Option<usize>,
}

/// Rank the censused functions by total exclusive time and keep the top
/// `top_funcs` as their own series (the rest fold into `"other"`).
pub(crate) fn rank_census(
    c: &FuncCensus,
    mut name_of: impl FnMut(u32) -> String,
    top_funcs: Option<usize>,
) -> SeriesSpec {
    let mut by_total: Vec<(u32, f64)> = c
        .codes
        .iter()
        .copied()
        .zip(c.totals.iter().copied())
        .collect();
    let total_funcs = by_total.len();
    by_total.sort_by(|a, b| b.1.total_cmp(&a.1)); // stable: ties stay first-seen
    let keep = top_funcs.unwrap_or(total_funcs).min(total_funcs);
    let mut func_of_code: std::collections::HashMap<u32, usize> =
        std::collections::HashMap::new();
    let mut func_names: Vec<String> = Vec::new();
    for (code, _) in by_total.iter().take(keep) {
        func_of_code.insert(*code, func_names.len());
        func_names.push(name_of(*code));
    }
    let other_slot = if keep < total_funcs {
        func_names.push("other".to_string());
        Some(func_names.len() - 1)
    } else {
        None
    };
    SeriesSpec { func_of_code, func_names, other_slot }
}

/// The clipped overlap of one segment with every bin it touches inside
/// `[bins.0, bins.1)`, in ascending bin order — **the** binning
/// arithmetic, shared by every engine so per-cell f64 adds replay in the
/// same order with the same values everywhere.
#[inline]
pub(crate) fn seg_bin_overlaps(
    s: &Segment,
    t0: i64,
    width: f64,
    num_bins: usize,
    bins: (usize, usize),
    mut f: impl FnMut(usize, f64),
) {
    let lo_bin = ((((s.start - t0) as f64) / width).floor() as usize).max(bins.0);
    let hi_bin = (((((s.end - t0) as f64) / width).ceil() as usize).min(num_bins)).min(bins.1);
    for b in lo_bin..hi_bin {
        let bin_lo = t0 as f64 + b as f64 * width;
        let bin_hi = bin_lo + width;
        let ov = (s.end as f64).min(bin_hi) - (s.start as f64).max(bin_lo);
        if ov > 0.0 {
            f(b, ov);
        }
    }
}

/// The output series a censused name code feeds: its own ranked series
/// for top-k functions, `"other"` for the rest. None only for codes the
/// census never saw (impossible for segments the census was built from).
#[inline]
pub(crate) fn series_of_code(spec: &SeriesSpec, code: u32) -> Option<usize> {
    match spec.func_of_code.get(&code) {
        Some(&f) => Some(f),
        None => spec.other_slot,
    }
}

/// Accumulate segment overlap directly into the ranked output series
/// over the bins `[bins.0, bins.1)` — stage 3. Every (series, bin) cell
/// folds its contributions in segment order — including `"other"`, which
/// interleaves its member functions' contributions in that same global
/// order — so splitting the bin axis across workers and stitching ranges
/// back together is bit-identical to one pass, and so is replaying
/// per-shard (series, bin, overlap) lists in shard order (the streamed
/// drivers), because shard order *is* segment order.
///
/// Rows are O(series × bins): with a top-k ranking, partial state never
/// scales with the number of distinct function names.
pub(crate) fn bin_segments_series(
    segs: &[Segment],
    spec: &SeriesSpec,
    t0: i64,
    width: f64,
    num_bins: usize,
    bins: (usize, usize),
) -> Vec<Vec<f64>> {
    // SoA scratch: one flat row-major allocation (series × bin window)
    // instead of a Vec-of-Vecs — no per-series pointer chase on the hot
    // accumulate, and the whole scratch is cache-resident for top-k
    // rankings. The window clamp below repeats `seg_bin_overlaps`'s
    // float expressions exactly (the shared binning arithmetic — keep
    // them in lockstep) but runs branchless: integer min/max compile to
    // cmov, and zero-overlap edge bins accumulate `ov.max(0.0)` instead
    // of branching — bit-preserving, because cells start at +0.0 and
    // only ever add non-negative values (x + 0.0 == x, x + -0.0 == x).
    let w = bins.1 - bins.0;
    if w == 0 {
        return vec![Vec::new(); spec.func_names.len()];
    }
    let mut flat = vec![0.0f64; w * spec.func_names.len()];
    for s in segs {
        let Some(series) = series_of_code(spec, s.name_code) else { continue };
        let row = &mut flat[series * w..(series + 1) * w];
        let lo_bin = ((((s.start - t0) as f64) / width).floor() as usize).max(bins.0);
        let hi_bin = (((((s.end - t0) as f64) / width).ceil() as usize).min(num_bins)).min(bins.1);
        let (start, end) = (s.start as f64, s.end as f64);
        for b in lo_bin..hi_bin {
            let bin_lo = t0 as f64 + b as f64 * width;
            let bin_hi = bin_lo + width;
            let ov = end.min(bin_hi) - start.max(bin_lo);
            row[b - bins.0] += ov.max(0.0);
        }
    }
    flat.chunks(w).map(|c| c.to_vec()).collect()
}

/// The nested-Vec, branchy reference implementation of
/// [`bin_segments_series`] — kept as the baseline the
/// `stream_time_profile_soa` gate row measures the SoA kernel against
/// (via [`BinBench`]), and as the executable spec the SoA kernel must
/// stay bit-identical to.
pub(crate) fn bin_segments_series_ref(
    segs: &[Segment],
    spec: &SeriesSpec,
    t0: i64,
    width: f64,
    num_bins: usize,
    bins: (usize, usize),
) -> Vec<Vec<f64>> {
    let mut rows = vec![vec![0.0f64; bins.1 - bins.0]; spec.func_names.len()];
    for s in segs {
        let Some(series) = series_of_code(spec, s.name_code) else { continue };
        seg_bin_overlaps(s, t0, width, num_bins, bins, |b, ov| {
            rows[series][b - bins.0] += ov;
        });
    }
    rows
}

/// Bench-only harness for the series-binning kernels: `prepare` does the
/// segment extraction and ranking once, so `run_soa` / `run_ref` time
/// exactly the fold the streamed and sharded drivers run per shard.
#[doc(hidden)]
pub struct BinBench {
    segs: Vec<Segment>,
    spec: SeriesSpec,
    t0: i64,
    width: f64,
    num_bins: usize,
}

impl BinBench {
    pub fn prepare(trace: &mut Trace, num_bins: usize, top_funcs: Option<usize>) -> Result<Self> {
        if num_bins == 0 {
            bail!("num_bins must be > 0");
        }
        let (t0, t1) = trace.time_range()?;
        let segs = exclusive_segments(trace)?;
        let c = census(&segs);
        let (_, ndict) = trace.events.strs(COL_NAME)?;
        let spec =
            rank_census(&c, |code| ndict.resolve(code).unwrap_or("").to_string(), top_funcs);
        let span = (t1 - t0).max(1) as f64;
        let width = span / num_bins as f64;
        Ok(BinBench { segs, spec, t0, width, num_bins })
    }

    /// One SoA fold over all prepared segments; returns the binned total.
    pub fn run_soa(&self) -> f64 {
        let rows = bin_segments_series(
            &self.segs,
            &self.spec,
            self.t0,
            self.width,
            self.num_bins,
            (0, self.num_bins),
        );
        rows.iter().flatten().sum()
    }

    /// One reference fold; must produce bit-identical rows to `run_soa`.
    pub fn run_ref(&self) -> f64 {
        let rows = bin_segments_series_ref(
            &self.segs,
            &self.spec,
            self.t0,
            self.width,
            self.num_bins,
            (0, self.num_bins),
        );
        rows.iter().flatten().sum()
    }
}

/// Transpose series-major accumulation rows into the `values[bin][func]`
/// output layout (a pure copy — no arithmetic, so no ordering concerns).
pub(crate) fn values_from_series_rows(rows: &[Vec<f64>], num_bins: usize) -> Vec<Vec<f64>> {
    let nf = rows.len();
    let mut values = vec![vec![0.0f64; nf]; num_bins];
    for (series, row) in rows.iter().enumerate() {
        for (b, v) in row.iter().enumerate() {
            values[b][series] = *v;
        }
    }
    values
}

/// Compute a time profile with `num_bins` equal bins over the trace span.
/// If `top_funcs` is Some(k), only the k functions with the largest total
/// exclusive time get their own series; the rest add into `"other"` (per
/// cell in global segment order — the one canonical order every engine,
/// eager, bin-axis sharded, streamed and census-backed, reproduces).
pub fn time_profile(
    trace: &mut Trace,
    num_bins: usize,
    top_funcs: Option<usize>,
) -> Result<TimeProfile> {
    if num_bins == 0 {
        bail!("num_bins must be > 0");
    }
    let (t0, t1) = trace.time_range()?;
    let segs = exclusive_segments(trace)?;
    let c = census(&segs);
    let (_, ndict) = trace.events.strs(COL_NAME)?;
    let spec = rank_census(&c, |code| ndict.resolve(code).unwrap_or("").to_string(), top_funcs);

    let span = (t1 - t0).max(1) as f64;
    let width = span / num_bins as f64;
    let rows = bin_segments_series(&segs, &spec, t0, width, num_bins, (0, num_bins));
    let values = values_from_series_rows(&rows, num_bins);
    let bin_edges = (0..=num_bins)
        .map(|b| t0 + (b as f64 * width).round() as i64)
        .collect();
    Ok(TimeProfile { bin_edges, func_names: spec.func_names, values })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Trace {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.enter(0, 0, 20, "work");
        b.leave(0, 0, 80, "work");
        b.leave(0, 0, 100, "main");
        b.finish()
    }

    #[test]
    fn segments_are_exclusive() {
        let mut t = toy();
        let segs = exclusive_segments(&mut t).unwrap();
        let (_, d) = t.events.strs(COL_NAME).unwrap();
        let total: i64 = segs.iter().map(|s| s.end - s.start).sum();
        assert_eq!(total, 100); // no double counting
        let main_time: i64 = segs
            .iter()
            .filter(|s| d.resolve(s.name_code) == Some("main"))
            .map(|s| s.end - s.start)
            .sum();
        assert_eq!(main_time, 40); // 0-20 and 80-100
    }

    #[test]
    fn bins_sum_to_busy_time() {
        let mut t = toy();
        let tp = time_profile(&mut t, 10, None).unwrap();
        assert!((tp.total() - 100.0).abs() < 1e-9);
        assert_eq!(tp.num_bins(), 10);
        // bin 0 covers [0,10): all "main"
        let main_idx = tp.func_index("main").unwrap();
        assert_eq!(tp.values[0][main_idx], 10.0);
        let work_idx = tp.func_index("work").unwrap();
        // bin 2 covers [20,30): all "work"
        assert_eq!(tp.values[2][work_idx], 10.0);
    }

    #[test]
    fn top_funcs_folds_other() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.enter(0, 0, 10, "big");
        b.leave(0, 0, 90, "big");
        b.enter(0, 0, 92, "small");
        b.leave(0, 0, 94, "small");
        b.leave(0, 0, 100, "main");
        let mut t = b.finish();
        let tp = time_profile(&mut t, 4, Some(1)).unwrap();
        assert_eq!(tp.func_names[0], "big");
        assert!(tp.func_names.contains(&"other".to_string()));
        assert!((tp.total() - 100.0).abs() < 1e-9);
    }

    /// Jagged multi-proc trace: deep nesting, duplicate timestamps,
    /// zero-width calls, an unmatched enter — everything that stresses
    /// the bin-window clamp.
    fn jagged() -> Trace {
        let mut b = TraceBuilder::new();
        for p in 0..3i64 {
            b.enter(p, 0, p, "main");
            b.enter(p, 0, 7 + p * 3, "solve");
            b.enter(p, 0, 7 + p * 3, "leaf"); // same ts as parent enter
            b.leave(p, 0, 7 + p * 3, "leaf"); // zero-width call
            b.leave(p, 0, 41 + p, "solve");
            b.enter(p, 0, 41 + p, "io");
            b.leave(p, 0, 97, "io");
            b.leave(p, 0, 100 + p, "main");
        }
        b.enter(0, 1, 13, "orphan"); // unmatched enter on its own thread
        b.finish()
    }

    #[test]
    fn soa_binning_matches_reference_bitwise() {
        let mut t = jagged();
        let (t0, t1) = t.time_range().unwrap();
        let segs = exclusive_segments(&mut t).unwrap();
        let c = census(&segs);
        let (_, ndict) = t.events.strs(COL_NAME).unwrap();
        for top in [None, Some(1), Some(2)] {
            let spec =
                rank_census(&c, |code| ndict.resolve(code).unwrap_or("").to_string(), top);
            for num_bins in [1usize, 7, 64] {
                let width = (t1 - t0).max(1) as f64 / num_bins as f64;
                let full = bin_segments_series(&segs, &spec, t0, width, num_bins, (0, num_bins));
                let rf = bin_segments_series_ref(&segs, &spec, t0, width, num_bins, (0, num_bins));
                // f64 == is bitwise here: no NaNs, and the SoA kernel must
                // not even flip a zero sign vs the branchy reference.
                assert_eq!(full, rf, "top={top:?} num_bins={num_bins}");
                // Split bin windows (the sharded axis) must agree too,
                // including the empty left window when num_bins == 1.
                let mid = num_bins / 2;
                for bins in [(0, mid), (mid, num_bins)] {
                    let a = bin_segments_series(&segs, &spec, t0, width, num_bins, bins);
                    let r = bin_segments_series_ref(&segs, &spec, t0, width, num_bins, bins);
                    assert_eq!(a, r, "top={top:?} num_bins={num_bins} bins={bins:?}");
                }
            }
        }
    }

    #[test]
    fn bin_bench_kernels_agree() {
        let mut t = jagged();
        let bench = BinBench::prepare(&mut t, 16, Some(2)).unwrap();
        assert_eq!(bench.run_soa().to_bits(), bench.run_ref().to_bits());
        assert!(bench.run_soa() > 0.0);
        assert!(BinBench::prepare(&mut jagged(), 0, None).is_err());
    }

    #[test]
    fn multiprocess_sums_across_processes() {
        let mut b = TraceBuilder::new();
        for p in 0..4 {
            b.enter(p, 0, 0, "main");
            b.leave(p, 0, 100, "main");
        }
        let mut t = b.finish();
        let tp = time_profile(&mut t, 5, None).unwrap();
        // 4 processes x 100ns = 400ns busy, 80 per bin
        assert!((tp.total() - 400.0).abs() < 1e-9);
        assert!((tp.values[0][0] - 80.0).abs() < 1e-9);
    }
}
