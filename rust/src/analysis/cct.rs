//! `_create_cct` (paper §IV.A): the unified calling-context tree.
//!
//! One CCT for the whole trace, aggregated over time and across all
//! processes/threads (paper §III.C): each node is a distinct call *path*;
//! per-node statistics accumulate every invocation from every process.
//! Each Enter row gets a `_cct_node` column referencing its node, so
//! path-conditioned analyses can join back to events.

use crate::df::{Column, NULL_I64};
use crate::trace::*;
use anyhow::Result;
use std::collections::HashMap;

/// One node of the unified CCT.
#[derive(Debug, Clone, PartialEq)]
pub struct CctNode {
    pub id: usize,
    pub parent: Option<usize>,
    /// Function name (resolved).
    pub name: String,
    pub children: Vec<usize>,
    /// Number of invocations of this call path (across all procs/threads).
    pub count: u64,
    /// Total inclusive / exclusive ns accumulated at this path.
    pub time_inc: f64,
    pub time_exc: f64,
    /// Per-process inclusive ns (for cross-process discrepancy analysis).
    pub time_inc_by_proc: HashMap<i64, f64>,
}

/// The unified calling-context tree.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Cct {
    pub nodes: Vec<CctNode>,
    pub roots: Vec<usize>,
}

impl Cct {
    /// Depth-first preorder walk.
    pub fn preorder(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nodes.len());
        let mut stack: Vec<usize> = self.roots.iter().rev().copied().collect();
        while let Some(id) = stack.pop() {
            out.push(id);
            for &c in self.nodes[id].children.iter().rev() {
                stack.push(c);
            }
        }
        out
    }

    /// Root-to-node call path of names.
    pub fn path(&self, mut id: usize) -> Vec<&str> {
        let mut out = Vec::new();
        loop {
            out.push(self.nodes[id].name.as_str());
            match self.nodes[id].parent {
                Some(p) => id = p,
                None => break,
            }
        }
        out.reverse();
        out
    }

    /// Render as an indented tree with metrics (hpcviewer-style).
    pub fn render(&self, max_nodes: usize) -> String {
        let mut out = String::new();
        let mut count = 0;
        let mut stack: Vec<(usize, usize)> = self.roots.iter().rev().map(|&r| (r, 0)).collect();
        while let Some((id, depth)) = stack.pop() {
            if count >= max_nodes {
                out.push_str("...\n");
                break;
            }
            let n = &self.nodes[id];
            out.push_str(&format!(
                "{:indent$}{} [count={} inc={} exc={}]\n",
                "",
                n.name,
                n.count,
                crate::util::fmt_ns(n.time_inc),
                crate::util::fmt_ns(n.time_exc),
                indent = depth * 2
            ));
            count += 1;
            for &c in n.children.iter().rev() {
                stack.push((c, depth + 1));
            }
        }
        out
    }

    /// For each node, imbalance of inclusive time across processes:
    /// max(per-proc) / mean(per-proc). Nodes seen on a single process get 1.
    pub fn cross_process_imbalance(&self, id: usize) -> f64 {
        let m = &self.nodes[id].time_inc_by_proc;
        if m.is_empty() {
            return 1.0;
        }
        let max = m.values().copied().fold(f64::NEG_INFINITY, f64::max);
        let mean = m.values().sum::<f64>() / m.len() as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }
}

/// Build (or return the cached row→node mapping for) the unified CCT.
/// Adds the `_cct_node` column; returns the tree.
pub fn create_cct(trace: &mut Trace) -> Result<Cct> {
    super::metrics::calc_exc_metrics(trace)?;
    let n = trace.len();
    let pr = trace.events.i64s(COL_PROC)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let enter = edict.code_of(ENTER);
    let leave = edict.code_of(LEAVE);
    let inc = trace.events.f64s("time.inc")?;
    let exc = trace.events.f64s("time.exc")?;
    let th = trace.events.i64s(COL_THREAD)?;

    let mut cct = Cct::default();
    // (parent node or usize::MAX, name code) -> node id
    let mut index: HashMap<(usize, u32), usize> = HashMap::new();
    let mut node_of_row = vec![NULL_I64; n];
    // per (proc, thread) stack of node ids
    let mut stacks: HashMap<(i64, i64), Vec<usize>> = HashMap::new();

    for i in 0..n {
        let code = Some(et[i]);
        let stack = stacks.entry((pr[i], th[i])).or_default();
        if code == enter {
            let parent = stack.last().copied();
            let key = (parent.unwrap_or(usize::MAX), nm[i]);
            let id = *index.entry(key).or_insert_with(|| {
                let id = cct.nodes.len();
                cct.nodes.push(CctNode {
                    id,
                    parent,
                    name: ndict.resolve(nm[i]).unwrap_or("").to_string(),
                    children: Vec::new(),
                    count: 0,
                    time_inc: 0.0,
                    time_exc: 0.0,
                    time_inc_by_proc: HashMap::new(),
                });
                match parent {
                    Some(p) => cct.nodes[p].children.push(id),
                    None => cct.roots.push(id),
                }
                id
            });
            let node = &mut cct.nodes[id];
            node.count += 1;
            if !inc[i].is_nan() {
                node.time_inc += inc[i];
                *node.time_inc_by_proc.entry(pr[i]).or_insert(0.0) += inc[i];
            }
            if !exc[i].is_nan() {
                node.time_exc += exc[i];
            }
            node_of_row[i] = id as i64;
            stack.push(id);
        } else if code == leave {
            if let Some(id) = stack.pop() {
                node_of_row[i] = id as i64;
            }
        } else if let Some(&id) = stack.last() {
            node_of_row[i] = id as i64;
        }
    }
    if !trace.events.has("_cct_node") {
        trace.events.push("_cct_node", Column::I64(node_of_row))?;
    }
    Ok(cct)
}

/// Merge partial CCTs built over process-aligned shards into the unified
/// tree, preserving the sequential first-seen node-id order.
///
/// Why this is bit-identical to [`create_cct`] over the whole trace:
/// within a shard, node ids are assigned in first-seen row order and a
/// node's parent is always created before it (`parent id < node id`), so
/// walking a partial's nodes in id order replays its key discoveries in
/// row order. Merging partials in shard order (= global row order)
/// therefore discovers every (parent-path, name) key in exactly the
/// order the sequential pass does — same ids, same children order, same
/// root order. Accumulated times are integer-valued nanosecond f64 sums
/// (exact, associative below 2^53) and per-process entries never
/// straddle shards (shards are process-aligned).
#[derive(Default)]
pub(crate) struct CctMerger {
    cct: Cct,
    /// (global parent id or usize::MAX for roots, name) -> global id.
    index: HashMap<(usize, String), usize>,
}

impl CctMerger {
    pub(crate) fn new() -> Self {
        Self::default()
    }

    /// Approximate heap bytes of the merged tree — the streamed driver's
    /// `peak_partial_bytes` estimate (O(tree), independent of rows).
    pub(crate) fn approx_bytes(&self) -> usize {
        self.cct.nodes.len() * (std::mem::size_of::<CctNode>() + 64)
    }

    /// Fold one shard's partial tree in; returns the shard-local → global
    /// node-id mapping (used to remap `_cct_node` columns).
    pub(crate) fn merge(&mut self, part: &Cct) -> Vec<usize> {
        let mut map = Vec::with_capacity(part.nodes.len());
        for node in &part.nodes {
            let gparent = node.parent.map(|p| map[p]);
            let key = (gparent.unwrap_or(usize::MAX), node.name.clone());
            let gid = match self.index.get(&key) {
                Some(&g) => {
                    let gn = &mut self.cct.nodes[g];
                    gn.count += node.count;
                    gn.time_inc += node.time_inc;
                    gn.time_exc += node.time_exc;
                    for (&p, &v) in &node.time_inc_by_proc {
                        *gn.time_inc_by_proc.entry(p).or_insert(0.0) += v;
                    }
                    g
                }
                None => {
                    let g = self.cct.nodes.len();
                    self.index.insert(key, g);
                    self.cct.nodes.push(CctNode {
                        id: g,
                        parent: gparent,
                        name: node.name.clone(),
                        children: Vec::new(),
                        count: node.count,
                        time_inc: node.time_inc,
                        time_exc: node.time_exc,
                        time_inc_by_proc: node.time_inc_by_proc.clone(),
                    });
                    match gparent {
                        Some(p) => self.cct.nodes[p].children.push(g),
                        None => self.cct.roots.push(g),
                    }
                    g
                }
            };
            map.push(gid);
        }
        map
    }

    pub(crate) fn finish(self) -> Cct {
        self.cct
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_proc() -> Trace {
        let mut b = TraceBuilder::new();
        for p in 0..2i64 {
            b.enter(p, 0, 0, "main");
            b.enter(p, 0, 10, "solve");
            b.enter(p, 0, 20, "MPI_Wait");
            b.leave(p, 0, 30 + 10 * p, "MPI_Wait");
            b.leave(p, 0, 50 + 10 * p, "solve");
            b.enter(p, 0, 60 + 10 * p, "io");
            b.leave(p, 0, 70 + 10 * p, "io");
            b.leave(p, 0, 100, "main");
        }
        b.finish()
    }

    #[test]
    fn unified_across_processes() {
        let mut t = two_proc();
        let cct = create_cct(&mut t).unwrap();
        // one tree: main -> {solve -> MPI_Wait, io}
        assert_eq!(cct.roots.len(), 1);
        assert_eq!(cct.nodes.len(), 4);
        let root = &cct.nodes[cct.roots[0]];
        assert_eq!(root.name, "main");
        assert_eq!(root.count, 2); // both processes merged into one path
        assert_eq!(root.time_inc, 200.0);
    }

    #[test]
    fn paths_and_preorder() {
        let mut t = two_proc();
        let cct = create_cct(&mut t).unwrap();
        let wait = cct.nodes.iter().find(|n| n.name == "MPI_Wait").unwrap();
        assert_eq!(cct.path(wait.id), vec!["main", "solve", "MPI_Wait"]);
        let pre = cct.preorder();
        assert_eq!(pre.len(), 4);
        assert_eq!(cct.nodes[pre[0]].name, "main");
    }

    #[test]
    fn same_name_different_paths_are_distinct_nodes() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.enter(0, 0, 1, "a");
        b.enter(0, 0, 2, "util"); // main/a/util
        b.leave(0, 0, 3, "util");
        b.leave(0, 0, 4, "a");
        b.enter(0, 0, 5, "b");
        b.enter(0, 0, 6, "util"); // main/b/util — distinct path
        b.leave(0, 0, 7, "util");
        b.leave(0, 0, 8, "b");
        b.leave(0, 0, 9, "main");
        let mut t = b.finish();
        let cct = create_cct(&mut t).unwrap();
        let utils: Vec<_> = cct.nodes.iter().filter(|n| n.name == "util").collect();
        assert_eq!(utils.len(), 2);
    }

    #[test]
    fn imbalance_reflects_process_skew() {
        let mut t = two_proc();
        let cct = create_cct(&mut t).unwrap();
        let wait = cct.nodes.iter().find(|n| n.name == "MPI_Wait").unwrap();
        // proc 0 waits 10ns, proc 1 waits 20ns -> max/mean = 20/15
        let imb = cct.cross_process_imbalance(wait.id);
        assert!((imb - 20.0 / 15.0).abs() < 1e-9);
    }

    #[test]
    fn merging_per_process_partials_equals_whole_trace_cct() {
        let mut whole = two_proc();
        let want = create_cct(&mut whole).unwrap();
        let mut merger = CctMerger::new();
        for p in 0..2 {
            let mut sub = whole
                .filter(&crate::df::Expr::process_eq(p))
                .unwrap();
            let part = create_cct(&mut sub).unwrap();
            let map = merger.merge(&part);
            assert_eq!(map.len(), part.nodes.len());
        }
        assert_eq!(merger.finish(), want);
    }

    #[test]
    fn cct_node_column_set_on_enters() {
        let mut t = two_proc();
        create_cct(&mut t).unwrap();
        let col = t.events.i64s("_cct_node").unwrap();
        let (et, edict) = t.events.strs(COL_TYPE).unwrap();
        let enter = edict.code_of(ENTER).unwrap();
        for i in 0..t.len() {
            if et[i] == enter {
                assert_ne!(col[i], NULL_I64, "row {i}");
            }
        }
    }
}
