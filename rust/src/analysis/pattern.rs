//! `pattern_detection` (paper §IV.D, Fig. 8): find repeating structure in
//! the trace with a z-normalized matrix profile (the paper uses STUMPY).
//!
//! The trace is reduced to a time series (per-bin total activity from the
//! time profile); the matrix profile of that series finds motifs =
//! iterations of the application's main loop. Two interchangeable
//! profile engines:
//! * [`matrix_profile`] — pure-Rust STOMP (O(n²) with O(1) inner update);
//! * the PJRT path (`runtime::ops::matrix_profile_hlo`) — the AOT Pallas
//!   kernel, used by the coordinator; both are tested to agree.
//!
//! [`detect_pattern`] implements the paper's user-facing API: given an
//! optional `start_event`, return time ranges of detected iterations
//! (`patterns[0]` = the first detected iteration, as in Fig. 8).

use super::time_profile::time_profile;
use crate::trace::*;
use anyhow::{bail, Result};

/// z-normalized squared-distance matrix profile (self-join) with exclusion
/// zone m/2. Returns (profile², nearest-neighbor index) per window.
/// STOMP: row 0 by direct dot products, then O(1) incremental updates.
pub fn matrix_profile(series: &[f64], m: usize) -> Result<(Vec<f64>, Vec<usize>)> {
    let n = series.len();
    if m < 2 || n < 2 * m {
        bail!("series too short for window {m} (len {n})");
    }
    let w = n - m + 1;
    let excl = (m / 2).max(1);

    // running stats
    let mut mu = vec![0.0f64; w];
    let mut sig = vec![0.0f64; w];
    {
        let mut s = 0.0;
        let mut s2 = 0.0;
        for i in 0..n {
            s += series[i];
            s2 += series[i] * series[i];
            if i >= m {
                s -= series[i - m];
                s2 -= series[i - m] * series[i - m];
            }
            if i + 1 >= m {
                let j = i + 1 - m;
                mu[j] = s / m as f64;
                sig[j] = (s2 / m as f64 - mu[j] * mu[j]).max(0.0).sqrt().max(1e-9);
            }
        }
    }

    let mut profile = vec![f64::INFINITY; w];
    let mut index = vec![0usize; w];
    // first row of QT: dot(T[0..m], T[j..j+m])
    let mut qt = vec![0.0f64; w];
    for j in 0..w {
        let mut acc = 0.0;
        for k in 0..m {
            acc += series[k] * series[j + k];
        }
        qt[j] = acc;
    }
    let qt_row0 = qt.clone();
    let mf = m as f64;
    for i in 0..w {
        if i > 0 {
            // update QT in place, descending j so qt[j-1] is the old value
            for j in (1..w).rev() {
                qt[j] = qt[j - 1] - series[i - 1] * series[j - 1]
                    + series[i + m - 1] * series[j + m - 1];
            }
            qt[0] = qt_row0[i]; // symmetry: QT[i][0] == QT[0][i]
        }
        for j in 0..w {
            if (i as i64 - j as i64).unsigned_abs() < excl as u64 {
                continue;
            }
            let corr = (qt[j] - mf * mu[i] * mu[j]) / (mf * sig[i] * sig[j]);
            let d2 = (2.0 * mf * (1.0 - corr)).max(0.0);
            if d2 < profile[i] {
                profile[i] = d2;
                index[i] = j;
            }
        }
    }
    Ok((profile, index))
}

/// A detected pattern occurrence: a time range of the trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PatternRange {
    pub start: i64,
    pub end: i64,
}

/// Configuration for [`detect_pattern`].
#[derive(Debug, Clone)]
pub struct PatternConfig {
    /// Bins for the activity series (profile resolution).
    pub bins: usize,
    /// Subsequence length in bins; None = inferred from start_event gaps
    /// or bins/16.
    pub window: Option<usize>,
}

impl Default for PatternConfig {
    fn default() -> Self {
        PatternConfig { bins: 512, window: None }
    }
}

/// Collect anchored-detection inputs from rows `[range.0, range.1)`:
/// Enter timestamps of `name` on process `p0`, plus whether `name` is
/// known to this trace's name dictionary (the "not present" error tests
/// dictionary membership, matching the sequential engine — stream shards
/// OR their per-shard verdicts). Shards call this for their own ranges;
/// anchor lists concatenate.
pub fn collect_anchors(
    trace: &Trace,
    name: &str,
    p0: i64,
    range: (usize, usize),
) -> Result<(Vec<i64>, bool)> {
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let ts = trace.events.i64s(COL_TS)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let enter = edict.code_of(ENTER);
    let Some(code) = ndict.code_of(name) else {
        return Ok((Vec::new(), false));
    };
    let mut anchors = Vec::new();
    for i in range.0..range.1 {
        if Some(et[i]) == enter && nm[i] == code && pr[i] == p0 {
            anchors.push(ts[i]);
        }
    }
    Ok((anchors, true))
}

/// Turn anchor timestamps into iteration ranges — the anchored core
/// shared by the sequential, sharded and streamed drivers. Errors match
/// the sequential engine exactly.
pub fn ranges_from_anchors(
    mut anchors: Vec<i64>,
    name_seen: bool,
    name: &str,
    t1: i64,
) -> Result<Vec<PatternRange>> {
    if !name_seen {
        bail!("start_event '{name}' not present in trace");
    }
    anchors.sort_unstable();
    if anchors.len() < 2 {
        bail!("start_event '{name}' occurs {} time(s); need >= 2", anchors.len());
    }
    let mut out: Vec<PatternRange> = anchors
        .windows(2)
        .map(|w| PatternRange { start: w[0], end: w[1] })
        .collect();
    // close the final iteration at trace end
    out.push(PatternRange { start: *anchors.last().unwrap(), end: t1 });
    Ok(out)
}

/// The unanchored core: motif discovery over an already-computed binned
/// activity series (from any time-profile engine — sequential, sharded
/// or streamed all produce bit-identical series). `t0`/`t1` are the
/// global time range the series was binned over.
pub fn ranges_from_series(
    series: &[f64],
    cfg: &PatternConfig,
    t0: i64,
    t1: i64,
) -> Result<Vec<PatternRange>> {
    let m = cfg.window.unwrap_or((cfg.bins / 16).max(4));
    let (profile, index) = matrix_profile(series, m)?;
    let w = profile.len();
    // Near-constant windows (quiet regions, trace tails) z-normalize to
    // garbage — exclude them from motif selection.
    let series_std = {
        let mean = series.iter().sum::<f64>() / series.len() as f64;
        (series.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
            / series.len() as f64)
            .sqrt()
    };
    let min_sig = 1e-3 * series_std.max(1e-12);
    let lively = |i: usize| -> bool {
        let win = &series[i..i + m];
        let mu = win.iter().sum::<f64>() / m as f64;
        let var = win.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>() / m as f64;
        var.sqrt() > min_sig
    };
    // motif = lively window pair with minimal distance
    let (mut best, mut best_d) = (usize::MAX, f64::INFINITY);
    for i in 0..w {
        if profile[i] < best_d && lively(i) && lively(index[i]) {
            best_d = profile[i];
            best = i;
        }
    }
    if best == usize::MAX {
        bail!("no repeating structure found (series has no lively windows)");
    }
    // A window's nearest neighbor may sit ANY number of periods away (all
    // repeats are equally close); the fundamental period is the smallest
    // neighbor gap among windows whose distance is near the motif's.
    let tol = (best_d * 4.0).max(best_d + 1e-9).max(1e-6);
    let period = (0..w)
        .filter(|&i| profile[i] <= tol && lively(i) && lively(index[i]))
        .map(|i| (i as i64 - index[i] as i64).unsigned_abs() as usize)
        .filter(|&g| g > 0)
        .min()
        .unwrap_or(0);
    if period == 0 {
        bail!("degenerate motif");
    }
    // occurrences: every `period` bins starting from best % period
    let bin_w = (t1 - t0).max(1) as f64 / cfg.bins as f64;
    let first = best % period;
    let mut out = Vec::new();
    let mut b = first;
    while b + period <= cfg.bins {
        out.push(PatternRange {
            start: t0 + (b as f64 * bin_w) as i64,
            end: t0 + ((b + period) as f64 * bin_w) as i64,
        });
        b += period;
    }
    Ok(out)
}

/// Detect repeating patterns. With `start_event`, occurrences are anchored
/// at that function's Enter timestamps (the paper's
/// `detect_pattern(start_event='time-loop')`) and validated/refined with
/// the matrix profile of the activity series; without it, motif discovery
/// runs on the activity series alone. The sharded / streamed drivers
/// ([`crate::exec::ops::detect_pattern`],
/// [`crate::exec::stream::detect_pattern`]) share [`collect_anchors`],
/// [`ranges_from_anchors`] and [`ranges_from_series`], differing only in
/// how the anchors / activity series are gathered.
pub fn detect_pattern(
    trace: &mut Trace,
    start_event: Option<&str>,
    cfg: &PatternConfig,
) -> Result<Vec<PatternRange>> {
    let (t0, t1) = trace.time_range()?;
    if let Some(name) = start_event {
        // anchor at Enter events of `name` on the lowest-id process
        let p0 = trace.process_ids()?.first().copied().unwrap_or(0);
        let (anchors, seen) = collect_anchors(trace, name, p0, (0, trace.len()))?;
        return ranges_from_anchors(anchors, seen, name, t1);
    }
    // unanchored: motif discovery on the binned activity series
    let tp = time_profile(trace, cfg.bins, Some(16))?;
    ranges_from_series(&tp.bin_totals(), cfg, t0, t1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sine_series(n: usize, period: f64, noise_seed: u64) -> Vec<f64> {
        let mut rng = crate::util::rng::Rng::new(noise_seed);
        (0..n)
            .map(|i| {
                (2.0 * std::f64::consts::PI * i as f64 / period).sin()
                    + 0.05 * rng.normal()
            })
            .collect()
    }

    #[test]
    fn profile_of_periodic_series_is_near_zero() {
        let s = sine_series(512, 37.0, 1);
        let (p, _) = matrix_profile(&s, 32).unwrap();
        let min = p.iter().copied().fold(f64::INFINITY, f64::min);
        assert!(min < 0.5, "min={min}");
    }

    #[test]
    fn planted_motif_found() {
        let mut rng = crate::util::rng::Rng::new(3);
        let mut s: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let motif: Vec<f64> = (0..40)
            .map(|i| 5.0 * (i as f64 * 0.45).sin())
            .collect();
        s[100..140].copy_from_slice(&motif);
        s[400..440].copy_from_slice(&motif);
        let (p, idx) = matrix_profile(&s, 40).unwrap();
        assert!(p[100] < 1e-6);
        assert_eq!(idx[100], 400);
        assert_eq!(idx[400], 100);
    }

    #[test]
    fn respects_exclusion_zone() {
        let s = sine_series(300, 20.0, 2);
        let m = 20;
        let (_, idx) = matrix_profile(&s, m).unwrap();
        for (i, &j) in idx.iter().enumerate() {
            assert!((i as i64 - j as i64).unsigned_abs() >= (m / 2) as u64);
        }
    }

    #[test]
    fn rejects_too_short_series() {
        assert!(matrix_profile(&[1.0; 10], 8).is_err());
    }

    /// Iterative trace: time-loop called 5 times, anchored detection
    /// returns 5 iteration ranges.
    #[test]
    fn anchored_detection() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        for it in 0..5i64 {
            let t = 10 + it * 100;
            b.enter(0, 0, t, "time-loop");
            b.enter(0, 0, t + 10, "compute");
            b.leave(0, 0, t + 80, "compute");
            b.leave(0, 0, t + 90, "time-loop");
        }
        b.leave(0, 0, 520, "main");
        let mut t = b.finish();
        let pats =
            detect_pattern(&mut t, Some("time-loop"), &PatternConfig::default()).unwrap();
        assert_eq!(pats.len(), 5);
        assert_eq!(pats[0].start, 10);
        assert_eq!(pats[0].end, 110);
        // filter to one iteration, as in Fig. 8
        let one = t
            .filter(&crate::df::Expr::time_between(pats[0].start, pats[0].end))
            .unwrap();
        assert!(one.len() < t.len());
        assert!(one.len() >= 4);
    }

    #[test]
    fn unanchored_detection_finds_period() {
        // periodic activity: bursts every 128 time units, idle in between
        // (top-level bursts — an enclosing busy root would flatten the
        // activity series and there would be no signal to detect)
        let mut b = TraceBuilder::new();
        b.instant(0, 0, 0, "trace-begin"); // pin span to [0, 2048] so the
        b.instant(0, 0, 2048, "trace-end"); // bin width divides the period
        for it in 0..16i64 {
            let t = it * 128;
            b.enter(0, 0, t + 5, "burst");
            b.leave(0, 0, t + 69, "burst");
        }
        let mut t = b.finish();
        let pats = detect_pattern(
            &mut t,
            None,
            &PatternConfig { bins: 256, window: Some(16) },
        )
        .unwrap();
        assert!(!pats.is_empty());
        let period = pats[0].end - pats[0].start;
        // true period is 128; binned estimate within one bin width (8)
        assert!((period - 128).abs() <= 16, "period={period}");
    }
}
