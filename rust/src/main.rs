//! `pipit` — the L3 coordinator binary.
//!
//! See `pipit help` (or [`pipit::coordinator::cli::USAGE`]).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = pipit::coordinator::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
