//! Kernel-backed analysis operations: chunk/pad arbitrary-size inputs to
//! the fixed AOT shapes and dispatch to the PJRT executables.
//!
//! These produce the same results as the pure-Rust engines in
//! [`crate::analysis`] (integration-tested); the coordinator prefers them
//! when a [`Runtime`] is loaded.

use super::Runtime;
use crate::analysis::comm::{CommMatrix, CommUnit};
use crate::analysis::time_profile::{exclusive_segments, TimeProfile};
use crate::df::NULL_I64;
use crate::trace::{Trace, COL_MSG_SIZE, COL_NAME, COL_PARTNER, COL_PROC, SEND_EVENT};
use anyhow::Result;

/// Matrix profile of an arbitrary-length series via the fixed-shape AOT
/// artifact. Series longer than one call are processed in overlapping
/// chunks (overlap = one window so no boundary is missed); shorter series
/// are padded with a linear ramp (non-constant, so z-norm stays finite)
/// and the padded windows are discarded.
pub fn matrix_profile_hlo(rt: &Runtime, series: &[f64], m: usize) -> Result<Vec<f64>> {
    let c = rt.contract;
    anyhow::ensure!(
        m == c.mp_m,
        "AOT matrix-profile window is {}, got {m}",
        c.mp_m
    );
    let n = series.len();
    anyhow::ensure!(n >= 2 * m, "series too short");
    let w = n - m + 1;
    let mut profile = vec![f64::INFINITY; w];

    let chunk_windows = c.mp_windows;
    let mut start = 0usize; // first window of this chunk
    loop {
        // chunk covers windows [start, start + chunk_windows)
        let mut buf = vec![0f32; c.mp_series_len];
        let avail = (n - start).min(c.mp_series_len);
        for i in 0..avail {
            buf[i] = series[start + i] as f32;
        }
        // pad with a gentle ramp continuing the last value
        let last = if avail > 0 { buf[avail - 1] } else { 0.0 };
        for (k, slot) in buf[avail..].iter_mut().enumerate() {
            *slot = last + 0.001 * (k as f32 + 1.0);
        }
        let (p, _) = rt.matrix_profile_raw(&buf)?;
        let valid = (w - start).min(chunk_windows);
        // real (unpadded) windows in this chunk
        let real = if avail == c.mp_series_len {
            valid
        } else {
            avail.saturating_sub(m - 1).min(valid)
        };
        for i in 0..real {
            // chunked profile is an upper bound of the global one: the
            // chunk sees a subset of candidate neighbors.
            profile[start + i] = profile[start + i].min(p[i] as f64);
        }
        if start + chunk_windows >= w {
            break;
        }
        start += chunk_windows - m; // overlap by one window length
    }
    Ok(profile)
}

/// Time profile via the AOT time-hist artifact. Produces the same
/// `TimeProfile` as [`crate::analysis::time_profile`] with
/// `num_bins = contract.th_bins` and top `contract.th_funcs - 1` functions
/// (+ "other").
pub fn time_profile_hlo(rt: &Runtime, trace: &mut Trace) -> Result<TimeProfile> {
    let c = rt.contract;
    let (t0, t1) = trace.time_range()?;
    let segs = exclusive_segments(trace)?;
    let (_, ndict) = trace.events.strs(COL_NAME)?;

    // rank functions by total exclusive time; top F-1 + "other"
    let mut totals: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
    for s in &segs {
        *totals.entry(s.name_code).or_insert(0.0) += (s.end - s.start) as f64;
    }
    let mut by_total: Vec<(u32, f64)> = totals.into_iter().collect();
    by_total.sort_by(|a, b| b.1.total_cmp(&a.1));
    let keep = by_total.len().min(c.th_funcs - 1);
    let mut slot_of: std::collections::HashMap<u32, i32> = std::collections::HashMap::new();
    let mut func_names = Vec::with_capacity(keep + 1);
    for (k, (code, _)) in by_total.iter().take(keep).enumerate() {
        slot_of.insert(*code, k as i32);
        func_names.push(ndict.resolve(*code).unwrap_or("").to_string());
    }
    let other_slot = keep as i32;
    let has_other = keep < by_total.len();
    if has_other {
        func_names.push("other".to_string());
    }

    let span = (t1 - t0).max(1) as f64;
    let bw = (span / c.th_bins as f64) as f32;
    let mut acc = vec![0f64; c.th_bins * c.th_funcs];

    let mut starts = vec![0f32; c.th_events];
    let mut durs = vec![0f32; c.th_events];
    let mut fids = vec![-1i32; c.th_events];
    let mut fill = 0usize;
    let flush = |starts: &mut Vec<f32>,
                     durs: &mut Vec<f32>,
                     fids: &mut Vec<i32>,
                     fill: &mut usize,
                     acc: &mut Vec<f64>|
     -> Result<()> {
        if *fill == 0 {
            return Ok(());
        }
        let out = rt.time_hist_raw(starts, durs, fids, 0.0, bw)?;
        for (k, v) in out.iter().enumerate() {
            acc[k] += *v as f64;
        }
        starts.iter_mut().for_each(|v| *v = 0.0);
        durs.iter_mut().for_each(|v| *v = 0.0);
        fids.iter_mut().for_each(|v| *v = -1);
        *fill = 0;
        Ok(())
    };

    for s in &segs {
        let slot = match slot_of.get(&s.name_code) {
            Some(&k) => k,
            None if has_other => other_slot,
            None => continue,
        };
        starts[fill] = (s.start - t0) as f32;
        durs[fill] = (s.end - s.start) as f32;
        fids[fill] = slot;
        fill += 1;
        if fill == c.th_events {
            flush(&mut starts, &mut durs, &mut fids, &mut fill, &mut acc)?;
        }
    }
    flush(&mut starts, &mut durs, &mut fids, &mut fill, &mut acc)?;

    let nf = func_names.len();
    let values: Vec<Vec<f64>> = (0..c.th_bins)
        .map(|b| (0..nf).map(|f| acc[b * c.th_funcs + f]).collect())
        .collect();
    let bin_edges = (0..=c.th_bins)
        .map(|b| t0 + (b as f64 * span / c.th_bins as f64).round() as i64)
        .collect();
    Ok(TimeProfile { bin_edges, func_names, values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceBuilder;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    #[test]
    fn chunked_profile_detects_planted_motif() {
        let Some(rt) = runtime() else { return };
        let m = rt.contract.mp_m;
        // series longer than one AOT call
        let n = rt.contract.mp_series_len + 1500;
        let mut rng = crate::util::rng::Rng::new(5);
        let mut s: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let motif: Vec<f64> = (0..m).map(|i| 10.0 * (i as f64 * 0.3).sin()).collect();
        s[700..700 + m].copy_from_slice(&motif);
        s[n - 900..n - 900 + m].copy_from_slice(&motif);
        let p = matrix_profile_hlo(&rt, &s, m).unwrap();
        assert_eq!(p.len(), n - m + 1);
        // both motif windows match something closely... at least locally;
        // the second motif lies in a later chunk, but its *own* chunk
        // contains the first? No — chunks overlap by m, so only verify the
        // planted window has a markedly low profile vs the noise median.
        let mut sorted: Vec<f64> = p.iter().copied().filter(|v| v.is_finite()).collect();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = sorted[sorted.len() / 2];
        assert!(
            p[700] < median || p[n - 900] < median,
            "motif not distinguished: p700={} pn900={} median={median}",
            p[700],
            p[n - 900]
        );
    }

    #[test]
    fn hlo_comm_matrix_matches_rust() {
        let Some(rt) = runtime() else { return };
        let t = crate::gen::generate("laghos", &crate::gen::GenConfig::new(16, 8), 1).unwrap();
        for unit in [CommUnit::Bytes, CommUnit::Count] {
            let hlo = comm_matrix_hlo(&rt, &t, unit).unwrap();
            let rust = crate::analysis::comm_matrix(&t, unit).unwrap();
            assert_eq!(hlo.procs, rust.procs);
            for i in 0..hlo.n() {
                for j in 0..hlo.n() {
                    let (a, b) = (hlo.data[i][j], rust.data[i][j]);
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "[{i}][{j}] {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn hlo_time_profile_matches_rust() {
        let Some(rt) = runtime() else { return };
        let mut b = TraceBuilder::new();
        for p in 0..4i64 {
            b.enter(p, 0, 0, "main");
            let mut t = 10;
            for _ in 0..50 {
                b.enter(p, 0, t, "compute");
                t += 37;
                b.leave(p, 0, t, "compute");
                b.enter(p, 0, t, "mpi");
                t += 11;
                b.leave(p, 0, t, "mpi");
            }
            b.leave(p, 0, t + 10, "main");
        }
        let mut tr = b.finish();
        let hlo = time_profile_hlo(&rt, &mut tr).unwrap();
        let (bins, funcs) = (rt.contract.th_bins, rt.contract.th_funcs);
        let rust = crate::analysis::time_profile(&mut tr, bins, Some(funcs - 1)).unwrap();
        assert_eq!(hlo.func_names, rust.func_names);
        assert!((hlo.total() - rust.total()).abs() < 1e-2 * rust.total().max(1.0));
        for b in (0..hlo.num_bins()).step_by(13) {
            for f in 0..hlo.func_names.len() {
                let (a, c) = (hlo.values[b][f], rust.values[b][f]);
                assert!((a - c).abs() < 0.5 + 1e-3 * c.abs(), "bin {b} f {f}: {a} vs {c}");
            }
        }
    }
}


/// Communication matrix via the AOT comm-matrix artifact: message records
/// stream through the fixed-shape kernel in chunks; requires process ids
/// to fit the `cm_procs` rank slots (the session falls back to the Rust
/// engine otherwise).
pub fn comm_matrix_hlo(rt: &Runtime, trace: &Trace, unit: CommUnit) -> Result<CommMatrix> {
    let c = rt.contract;
    let procs = trace.process_ids()?;
    anyhow::ensure!(
        procs.iter().all(|&p| (0..c.cm_procs as i64).contains(&p)),
        "process ids exceed the {}-slot AOT contract",
        c.cm_procs
    );
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let ms = trace.events.i64s(COL_MSG_SIZE)?;
    let send = ndict.code_of(SEND_EVENT).unwrap_or(u32::MAX);

    let mut acc = vec![0f64; c.cm_procs * c.cm_procs];
    let mut src = vec![-1i32; c.cm_events];
    let mut dst = vec![-1i32; c.cm_events];
    let mut w = vec![0f32; c.cm_events];
    let mut fill = 0usize;
    let flush = |src: &mut Vec<i32>,
                 dst: &mut Vec<i32>,
                 w: &mut Vec<f32>,
                 fill: &mut usize,
                 acc: &mut Vec<f64>|
     -> Result<()> {
        if *fill == 0 {
            return Ok(());
        }
        let out = rt.comm_matrix_raw(src, dst, w)?;
        for (k, v) in out.iter().enumerate() {
            acc[k] += *v as f64;
        }
        src.iter_mut().for_each(|v| *v = -1);
        dst.iter_mut().for_each(|v| *v = -1);
        w.iter_mut().for_each(|v| *v = 0.0);
        *fill = 0;
        Ok(())
    };
    for i in 0..trace.len() {
        if nm[i] == send && pa[i] != NULL_I64 {
            src[fill] = pr[i] as i32;
            dst[fill] = pa[i] as i32;
            w[fill] = match unit {
                CommUnit::Count => 1.0,
                CommUnit::Bytes => ms[i].max(0) as f32,
            };
            fill += 1;
            if fill == c.cm_events {
                flush(&mut src, &mut dst, &mut w, &mut fill, &mut acc)?;
            }
        }
    }
    flush(&mut src, &mut dst, &mut w, &mut fill, &mut acc)?;

    // project the (cm_procs x cm_procs) accumulator onto the trace's ranks
    let data = procs
        .iter()
        .map(|&i| {
            procs
                .iter()
                .map(|&j| acc[i as usize * c.cm_procs + j as usize])
                .collect()
        })
        .collect();
    Ok(CommMatrix { procs, data })
}
