//! PJRT runtime: load AOT-compiled JAX+Pallas artifacts and execute them
//! from the analysis hot path. Python never runs here — the HLO text in
//! `artifacts/` was produced once by `make artifacts`.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! HloModuleProto with 64-bit ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/load_hlo/).

pub mod ops;

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Shape contract mirrored from `python/compile/model.py` (serialized to
/// artifacts/manifest.json at AOT time and re-checked at load).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeContract {
    pub mp_windows: usize,
    pub mp_m: usize,
    pub mp_series_len: usize,
    pub th_events: usize,
    pub th_bins: usize,
    pub th_funcs: usize,
    pub cm_events: usize,
    pub cm_procs: usize,
}

pub const DEFAULT_CONTRACT: ShapeContract = ShapeContract {
    mp_windows: 4096,
    mp_m: 64,
    mp_series_len: 4159,
    th_events: 8192,
    th_bins: 128,
    th_funcs: 64,
    cm_events: 8192,
    cm_procs: 64,
};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + the compiled artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    pub contract: ShapeContract,
    pub dir: PathBuf,
    matrix_profile: Option<Executable>,
    time_hist: Option<Executable>,
    comm_matrix: Option<Executable>,
}

impl Runtime {
    /// Create a runtime over the artifacts directory, compiling every
    /// artifact named in `manifest.json` once up front.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest_path = dir.join("manifest.json");
        let contract = if manifest_path.exists() {
            let text = std::fs::read_to_string(&manifest_path)?;
            let j = crate::util::json::Json::parse(&text)
                .context("parsing artifacts/manifest.json")?;
            let get = |k: &str| -> Result<usize> {
                j.get_f64(k)
                    .map(|v| v as usize)
                    .with_context(|| format!("manifest missing '{k}'"))
            };
            ShapeContract {
                mp_windows: get("mp_windows")?,
                mp_m: get("mp_m")?,
                mp_series_len: get("mp_series_len")?,
                th_events: get("th_events")?,
                th_bins: get("th_bins")?,
                th_funcs: get("th_funcs")?,
                cm_events: get("cm_events").unwrap_or(8192),
                cm_procs: get("cm_procs").unwrap_or(64),
            }
        } else {
            bail!(
                "no manifest.json in {} — run `make artifacts` first",
                dir.display()
            );
        };
        if contract.mp_series_len != contract.mp_windows + contract.mp_m - 1 {
            bail!("manifest shape contract is inconsistent");
        }
        let mut rt = Runtime {
            client,
            contract,
            dir: dir.clone(),
            matrix_profile: None,
            time_hist: None,
            comm_matrix: None,
        };
        rt.matrix_profile = Some(rt.compile_artifact("matrix_profile")?);
        rt.time_hist = Some(rt.compile_artifact("time_hist")?);
        // optional (older artifact dirs may predate it)
        rt.comm_matrix = rt.compile_artifact("comm_matrix").ok();
        Ok(rt)
    }

    fn compile_artifact(&self, name: &str) -> Result<Executable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(Executable { name: name.to_string(), exe })
    }

    /// Execute the matrix-profile artifact on exactly `mp_series_len`
    /// samples. Returns (profile², neighbor index) of length `mp_windows`.
    pub fn matrix_profile_raw(&self, series: &[f32]) -> Result<(Vec<f32>, Vec<i32>)> {
        let c = &self.contract;
        if series.len() != c.mp_series_len {
            bail!(
                "matrix_profile expects {} samples, got {}",
                c.mp_series_len,
                series.len()
            );
        }
        let exe = self.matrix_profile.as_ref().context("artifact not loaded")?;
        let x = xla::Literal::vec1(series);
        let result = exe.exe.execute::<xla::Literal>(&[x])?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        if tuple.len() != 2 {
            bail!("matrix_profile artifact returned {} outputs", tuple.len());
        }
        let profile = tuple[0].to_vec::<f32>()?;
        let index = tuple[1].to_vec::<i32>()?;
        Ok((profile, index))
    }

    /// Execute the time-hist artifact on exactly `th_events` intervals.
    /// Returns a (th_bins × th_funcs) row-major matrix.
    pub fn time_hist_raw(
        &self,
        starts: &[f32],
        durs: &[f32],
        fids: &[i32],
        t0: f32,
        bin_width: f32,
    ) -> Result<Vec<f32>> {
        let c = &self.contract;
        if starts.len() != c.th_events || durs.len() != c.th_events || fids.len() != c.th_events {
            bail!("time_hist expects {} events", c.th_events);
        }
        let exe = self.time_hist.as_ref().context("artifact not loaded")?;
        let args = [
            xla::Literal::vec1(starts),
            xla::Literal::vec1(durs),
            xla::Literal::vec1(fids),
            xla::Literal::scalar(t0),
            xla::Literal::scalar(bin_width),
        ];
        let result = exe.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let out = tuple
            .first()
            .context("time_hist artifact returned no outputs")?
            .to_vec::<f32>()?;
        if out.len() != c.th_bins * c.th_funcs {
            bail!("time_hist output length {} != bins*funcs", out.len());
        }
        Ok(out)
    }

    /// Execute the comm-matrix artifact on exactly `cm_events` message
    /// records. Returns a (cm_procs x cm_procs) row-major matrix.
    pub fn comm_matrix_raw(&self, src: &[i32], dst: &[i32], nbytes: &[f32]) -> Result<Vec<f32>> {
        let c = &self.contract;
        if src.len() != c.cm_events || dst.len() != c.cm_events || nbytes.len() != c.cm_events {
            bail!("comm_matrix expects {} records", c.cm_events);
        }
        let exe = self.comm_matrix.as_ref().context("comm_matrix artifact not loaded")?;
        let args = [
            xla::Literal::vec1(src),
            xla::Literal::vec1(dst),
            xla::Literal::vec1(nbytes),
        ];
        let result = exe.exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        let out = tuple
            .first()
            .context("comm_matrix artifact returned no outputs")?
            .to_vec::<f32>()?;
        if out.len() != c.cm_procs * c.cm_procs {
            bail!("comm_matrix output length {} != procs^2", out.len());
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime() -> Option<Runtime> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: run `make artifacts` first");
            return None;
        }
        Some(Runtime::load(dir).expect("runtime load"))
    }

    #[test]
    fn loads_and_validates_manifest() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.contract, DEFAULT_CONTRACT);
    }

    #[test]
    fn matrix_profile_executes_and_matches_rust() {
        let Some(rt) = runtime() else { return };
        let c = rt.contract;
        // periodic series with noise
        let mut rng = crate::util::rng::Rng::new(17);
        let series: Vec<f32> = (0..c.mp_series_len)
            .map(|i| {
                ((2.0 * std::f64::consts::PI * i as f64 / 199.0).sin()
                    + 0.05 * rng.normal()) as f32
            })
            .collect();
        let (profile, index) = rt.matrix_profile_raw(&series).unwrap();
        assert_eq!(profile.len(), c.mp_windows);
        assert_eq!(index.len(), c.mp_windows);

        // agree with the pure-Rust STOMP engine
        let series64: Vec<f64> = series.iter().map(|&v| v as f64).collect();
        let (rust_p, _) =
            crate::analysis::pattern::matrix_profile(&series64, c.mp_m).unwrap();
        for i in (0..c.mp_windows).step_by(97) {
            let a = profile[i] as f64;
            let b = rust_p[i];
            assert!(
                (a - b).abs() < 5e-2 * (1.0 + b.abs()),
                "window {i}: hlo={a} rust={b}"
            );
        }
    }

    #[test]
    fn time_hist_executes_and_matches_rust_binning() {
        let Some(rt) = runtime() else { return };
        let c = rt.contract;
        let mut rng = crate::util::rng::Rng::new(23);
        let mut starts = vec![0f32; c.th_events];
        let mut durs = vec![0f32; c.th_events];
        let mut fids = vec![-1i32; c.th_events];
        for i in 0..4000 {
            starts[i] = rng.uniform(0.0, 1000.0) as f32;
            durs[i] = rng.exponential(5.0) as f32;
            fids[i] = rng.below(c.th_funcs as u64) as i32;
        }
        let bw = 1000.0 / c.th_bins as f32;
        let out = rt.time_hist_raw(&starts, &durs, &fids, 0.0, bw).unwrap();
        // reference accumulation
        let mut want = vec![0f64; c.th_bins * c.th_funcs];
        for i in 0..c.th_events {
            if fids[i] < 0 {
                continue;
            }
            let (s, e) = (starts[i] as f64, (starts[i] + durs[i]) as f64);
            for b in 0..c.th_bins {
                let lo = b as f64 * bw as f64;
                let hi = lo + bw as f64;
                let ov = (e.min(hi) - s.max(lo)).max(0.0);
                want[b * c.th_funcs + fids[i] as usize] += ov;
            }
        }
        for k in (0..want.len()).step_by(131) {
            assert!(
                (out[k] as f64 - want[k]).abs() < 1e-2 * (1.0 + want[k].abs()),
                "k={k}: hlo={} want={}",
                out[k],
                want[k]
            );
        }
    }
}
