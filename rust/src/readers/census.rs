//! `TraceCensus`: the versioned, reader-agnostic pre-scan metadata record.
//!
//! The streamability pre-scans (csv/chrome byte-cursor walks, the otf2
//! `defs.bin` trailing section written at archive creation) already touch
//! every record of a trace before any shard decodes. This module gives
//! that walk a payload worth carrying — the per-interval index idea from
//! Traveler applied to streamed ingest:
//!
//! * **per-block metadata** ([`BlockCensus`]): row count and timestamp
//!   extrema of every process block / rank shard — the global span folds
//!   from these, and per-shard facts can be validated against them;
//! * **function census** ([`FuncTotals`]): every function that produces
//!   at least one exclusive segment, in *first-seen segment order*, with
//!   its total exclusive nanoseconds. This is exactly the census + rank
//!   input of [`crate::analysis::time_profile`], known before ingest —
//!   so the streamed `time_profile` bins only the top-k + `"other"`
//!   series directly, retiring its O(all-functions × bins) slot rows;
//! * **channel census** ([`ChannelCensus`]): per-(src, dst, tag) send /
//!   recv endpoint counts. The streamed message matcher pairs and drains
//!   a channel the moment its counts are complete, bounding matcher
//!   residency to the open-channel window instead of O(endpoints);
//! * **message-size extrema** ([`MsgCensus`]): the streamed
//!   `message_histogram` derives its bin width up front and folds
//!   straight into O(bins) counts, dropping the end-of-stream re-bin.
//!
//! The record is versioned ([`CENSUS_VERSION`]) and checksummed where it
//! is serialized (the otf2 trailing section): a corrupt or truncated
//! section degrades to "census absent" — the census-less fallback paths
//! — never to an error or a silently wrong census.
//!
//! # Determinism contract
//!
//! [`CensusAccum`] reproduces the engines' function census *exactly*: it
//! buffers each block's Enter/Leave events, stable-sorts them by
//! (thread, timestamp) — the same canonical sort
//! [`crate::trace::TraceBuilder::finish`] applies to decoded rows — and
//! runs the same stack walk as
//! [`crate::analysis::time_profile::exclusive_segments`]. First-seen
//! order and integer-ns totals therefore match the decoded trace's
//! census bit-for-bit, which is what keeps the census-backed streamed
//! `time_profile` identical to the sequential engine.

use crate::df::Interner;
use std::collections::{BTreeMap, HashMap};

/// Current census record version. Serialized censuses with a different
/// version are ignored (treated as absent), never misparsed.
pub const CENSUS_VERSION: u64 = 1;

/// Per-block (process block / rank shard) metadata, in shard order.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct BlockCensus {
    /// Trace rows the block decodes into.
    pub rows: u64,
    /// (min, max) timestamp over the block's rows; None for empty blocks.
    pub span: Option<(i64, i64)>,
}

/// Stream-wide function census: names in first-seen exclusive-segment
/// order with total exclusive time — the rank hints for top-k binning.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FuncTotals {
    /// Function names in first-seen segment order.
    pub names: Vec<String>,
    /// Total exclusive nanoseconds per name, same order (integer-valued,
    /// so folding them as f64 is exact).
    pub exc_ns: Vec<i64>,
}

/// One (src, dst, tag) channel's endpoint totals over the whole stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChannelCensus {
    pub src: i64,
    pub dst: i64,
    pub tag: i64,
    /// Send records the stream will yield on this channel.
    pub sends: u64,
    /// Recv records the stream will yield on this channel.
    pub recvs: u64,
}

/// Per-block function / channel sub-census — one row of the block ×
/// function (and block × channel) matrix. Slots index the stream-wide
/// [`FuncTotals`] / channel sections, so the global totals are exactly
/// the column sums of these rows. Consumers use them to pre-size
/// per-process fold outputs and to validate a single block against the
/// census instead of degrading the whole run.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BlockDetail {
    /// (slot into `funcs.names`, exclusive ns accounted in this block),
    /// ascending by slot.
    pub funcs: Vec<(u32, i64)>,
    /// (slot into `channels`, sends, recvs) recorded in this block,
    /// ascending by slot.
    pub channels: Vec<(u32, u64, u64)>,
}

/// Stream-wide message-size extrema (clamped sizes, mirroring the comm
/// analyses): enough to derive `message_histogram`'s bin width up front.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MsgCensus {
    /// Max clamped send size; -1 when no send record exists.
    pub max_send: i64,
    /// Max clamped recv size; -1 when no recv record exists.
    pub max_recv: i64,
    /// True when any send record with a non-null partner exists — the
    /// recv-only fallback decision, known before ingest.
    pub saw_send: bool,
}

/// The full pre-scan census. Every section is optional: a source can
/// carry per-block metadata but forfeit the function census (e.g. a row
/// the decode will reject), and consumers fall back per section.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceCensus {
    pub version: u64,
    pub blocks: Vec<BlockCensus>,
    pub funcs: Option<FuncTotals>,
    pub channels: Option<Vec<ChannelCensus>>,
    pub msgs: Option<MsgCensus>,
    /// Per-block sub-censuses, 1:1 with `blocks`; None for sources that
    /// only carry the aggregate sections (e.g. the otf2 defs trailer).
    pub block_detail: Option<Vec<BlockDetail>>,
}

impl TraceCensus {
    /// Global (min, max) timestamp folded from the per-block extrema;
    /// None when every block is empty.
    pub fn span(&self) -> Option<(i64, i64)> {
        let mut out: Option<(i64, i64)> = None;
        for b in &self.blocks {
            if let Some((lo, hi)) = b.span {
                out = Some(match out {
                    Some((a, z)) => (a.min(lo), z.max(hi)),
                    None => (lo, hi),
                });
            }
        }
        out
    }

    /// Total rows across all blocks.
    pub fn total_rows(&self) -> u64 {
        self.blocks.iter().map(|b| b.rows).sum()
    }

    /// Channel key → (send count, recv count), for the windowed matcher.
    pub fn channel_map(&self) -> Option<HashMap<(i64, i64, i64), (u64, u64)>> {
        self.channels.as_ref().map(|cs| {
            cs.iter()
                .map(|c| ((c.src, c.dst, c.tag), (c.sends, c.recvs)))
                .collect()
        })
    }
}

/// FNV-1a 32-bit checksum — guards the serialized census section against
/// bit flips (a lying census would silently corrupt the windowed-drain
/// pairing; a detected one just disables it).
pub(crate) fn fnv32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Frame-arena sentinel: "no frame" (empty stack / stack-bottom parent).
const NO_FRAME: u32 = u32::MAX;

/// One buffered Enter/Leave event awaiting the block's canonical sort.
#[derive(Debug, Clone, Copy)]
struct StackEvent {
    thread: i64,
    ts: i64,
    /// true = Enter, false = Leave.
    enter: bool,
    name: u32,
}

/// Incremental census builder fed by the pre-scans (and the otf2 writer)
/// one block at a time, in stream order. See the module docs for the
/// determinism contract.
#[derive(Debug, Default)]
pub(crate) struct CensusAccum {
    names: Interner,
    /// name code → total exclusive ns, slots in first-seen segment order.
    slot_of_code: HashMap<u32, usize>,
    codes: Vec<u32>,
    totals: Vec<i64>,
    /// funcs forfeited (a row the decode will reject was seen).
    forfeited: bool,
    /// SoA frame arena replacing per-stream `Vec<(name, start)>` call
    /// stacks: `frame_names`/`frame_starts`/`frame_parents` are parallel
    /// flat columns, each stream's stack is the parent-linked chain from
    /// `tops[stream]`, and popped slots recycle through `free` — so the
    /// arena holds exactly the live frames (max concurrent nesting
    /// across streams), in three dense allocations instead of one heap
    /// `Vec` per (proc, thread) stream. Same walk, same account order.
    frame_names: Vec<u32>,
    frame_starts: Vec<i64>,
    /// parent frame index; [`NO_FRAME`] for stack bottoms.
    frame_parents: Vec<u32>,
    /// per-stream top frame index; [`NO_FRAME`] when the stack is empty
    /// (persists across blocks, like the stacks it replaces).
    tops: Vec<u32>,
    /// recycled frame slots.
    free: Vec<u32>,
    stream_of: HashMap<(i64, i64), usize>,
    cur_key: Option<(i64, i64)>,
    cur: usize,
    /// the block in progress.
    block_rows: u64,
    block_span: Option<(i64, i64)>,
    block_events: Vec<StackEvent>,
    blocks: Vec<BlockCensus>,
    /// channel key → (sends, recvs), insertion-ordered for determinism.
    chan_index: HashMap<(i64, i64, i64), usize>,
    chan_keys: Vec<(i64, i64, i64)>,
    chan_counts: Vec<(u64, u64)>,
    msgs: MsgCensus,
    /// the block in progress's sub-census, keyed by global slot (sorted
    /// maps so the flushed rows are slot-ascending, deterministically).
    block_funcs: BTreeMap<u32, i64>,
    block_chans: BTreeMap<u32, (u64, u64)>,
    details: Vec<BlockDetail>,
}

impl CensusAccum {
    pub(crate) fn new() -> Self {
        CensusAccum {
            msgs: MsgCensus { max_send: -1, max_recv: -1, saw_send: false },
            ..Default::default()
        }
    }

    /// Forfeit the census (the decode will reject a row, or an event
    /// could not be interpreted); block/channel/msg sections are
    /// forfeited too — a census that might disagree with the decoded
    /// rows must not exist at all. Everything accumulated so far is
    /// dropped and every later call becomes a no-op, so a forfeited
    /// pre-scan costs no more than the plain streamability scan.
    pub(crate) fn forfeit(&mut self) {
        *self = CensusAccum { forfeited: true, ..CensusAccum::new() };
    }

    /// Record one decoded-row contribution to the current block's count
    /// and extrema. Call once per row the block will decode into.
    pub(crate) fn row(&mut self, ts: i64) {
        if self.forfeited {
            return;
        }
        self.block_rows += 1;
        self.block_span = Some(match self.block_span {
            Some((lo, hi)) => (lo.min(ts), hi.max(ts)),
            None => (ts, ts),
        });
    }

    /// Buffer an Enter event of the current block.
    pub(crate) fn enter(&mut self, thread: i64, ts: i64, name: &str) {
        if self.forfeited {
            return;
        }
        let name = self.names.intern(name);
        self.block_events.push(StackEvent { thread, ts, enter: true, name });
    }

    /// Buffer a Leave event of the current block.
    pub(crate) fn leave(&mut self, thread: i64, ts: i64, name: &str) {
        if self.forfeited {
            return;
        }
        let name = self.names.intern(name);
        self.block_events.push(StackEvent { thread, ts, enter: false, name });
    }

    /// Record a send endpoint (`partner` already in decoded form — pass
    /// `NULL_I64` only when the decoded row will carry it, in which case
    /// the matcher skips the row and so does the census).
    pub(crate) fn send(&mut self, proc: i64, partner: i64, tag: i64, size: i64) {
        if self.forfeited || partner == crate::df::NULL_I64 {
            return;
        }
        self.msgs.max_send = self.msgs.max_send.max(size.max(0));
        self.msgs.saw_send = true;
        let slot = self.chan_slot((proc, partner, tag));
        self.chan_counts[slot].0 += 1;
        self.block_chans.entry(slot as u32).or_default().0 += 1;
    }

    /// Record a recv endpoint (recv's partner = source rank).
    pub(crate) fn recv(&mut self, proc: i64, partner: i64, tag: i64, size: i64) {
        if self.forfeited || partner == crate::df::NULL_I64 {
            return;
        }
        self.msgs.max_recv = self.msgs.max_recv.max(size.max(0));
        let slot = self.chan_slot((partner, proc, tag));
        self.chan_counts[slot].1 += 1;
        self.block_chans.entry(slot as u32).or_default().1 += 1;
    }

    fn chan_slot(&mut self, key: (i64, i64, i64)) -> usize {
        let n = self.chan_keys.len();
        let slot = *self.chan_index.entry(key).or_insert(n);
        if slot == n {
            self.chan_keys.push(key);
            self.chan_counts.push((0, 0));
        }
        slot
    }

    /// Close the current block (its process id is `proc`): canonically
    /// sort the buffered Enter/Leave events and run the exclusive-time
    /// stack walk over them.
    pub(crate) fn end_block(&mut self, proc: i64) {
        if self.forfeited {
            return;
        }
        // the same stable (thread, ts) sort TraceBuilder::finish applies
        // (proc is constant within a block)
        let mut events = std::mem::take(&mut self.block_events);
        events.sort_by_key(|e| (e.thread, e.ts));
        for e in &events {
            self.walk(proc, e.thread, e.ts, e.enter, e.name);
        }
        self.blocks.push(BlockCensus { rows: self.block_rows, span: self.block_span });
        self.details.push(BlockDetail {
            funcs: std::mem::take(&mut self.block_funcs).into_iter().collect(),
            channels: std::mem::take(&mut self.block_chans)
                .into_iter()
                .map(|(slot, (s, r))| (slot, s, r))
                .collect(),
        });
        self.block_rows = 0;
        self.block_span = None;
    }

    /// One step of the `exclusive_segments` stack walk, over the SoA
    /// frame arena. Account calls happen in exactly the order the boxed
    /// per-stream stacks produced them: cut parent before push on Enter,
    /// emit child tail then resume parent on Leave.
    fn walk(&mut self, proc: i64, thread: i64, ts: i64, enter: bool, name: u32) {
        let key = (proc, thread);
        if self.cur_key != Some(key) {
            self.cur_key = Some(key);
            let tops = &mut self.tops;
            self.cur = *self.stream_of.entry(key).or_insert_with(|| {
                tops.push(NO_FRAME);
                tops.len() - 1
            });
        }
        let top = self.tops[self.cur];
        if enter {
            if top != NO_FRAME {
                let pstart = self.frame_starts[top as usize];
                if ts > pstart {
                    let pname = self.frame_names[top as usize];
                    self.account(pname, ts - pstart);
                }
                self.frame_starts[top as usize] = ts;
            }
            let f = match self.free.pop() {
                Some(f) => {
                    self.frame_names[f as usize] = name;
                    self.frame_starts[f as usize] = ts;
                    self.frame_parents[f as usize] = top;
                    f
                }
                None => {
                    let f = self.frame_names.len() as u32;
                    self.frame_names.push(name);
                    self.frame_starts.push(ts);
                    self.frame_parents.push(top);
                    f
                }
            };
            self.tops[self.cur] = f;
        } else if top != NO_FRAME {
            let cname = self.frame_names[top as usize];
            let cstart = self.frame_starts[top as usize];
            let parent = self.frame_parents[top as usize];
            self.free.push(top);
            self.tops[self.cur] = parent;
            if ts > cstart {
                self.account(cname, ts - cstart);
            }
            if parent != NO_FRAME {
                self.frame_starts[parent as usize] = ts;
            }
        }
    }

    /// Account one exclusive segment, assigning the next slot on first
    /// sight — the engines' first-seen census order.
    fn account(&mut self, code: u32, dur: i64) {
        let n = self.codes.len();
        let slot = *self.slot_of_code.entry(code).or_insert(n);
        if slot == n {
            self.codes.push(code);
            self.totals.push(0);
        }
        self.totals[slot] += dur;
        // the walk runs inside `end_block`, so this is the closing block
        *self.block_funcs.entry(slot as u32).or_insert(0) += dur;
    }

    /// Finish: the assembled census, or None when forfeited. A trailing
    /// unclosed block also forfeits: its process id is unknown here, and
    /// guessing one would mis-key the stack walk — callers close every
    /// block, so this only guards against misuse.
    pub(crate) fn finish(self) -> Option<TraceCensus> {
        if self.forfeited || self.block_rows > 0 || !self.block_events.is_empty() {
            return None;
        }
        let funcs = FuncTotals {
            names: self
                .codes
                .iter()
                .map(|&c| self.names.resolve(c).unwrap_or("").to_string())
                .collect(),
            exc_ns: self.totals,
        };
        let channels = self
            .chan_keys
            .iter()
            .zip(&self.chan_counts)
            .map(|(&(src, dst, tag), &(sends, recvs))| ChannelCensus {
                src,
                dst,
                tag,
                sends,
                recvs,
            })
            .collect();
        Some(TraceCensus {
            version: CENSUS_VERSION,
            blocks: self.blocks,
            funcs: Some(funcs),
            channels: Some(channels),
            msgs: Some(self.msgs),
            block_detail: Some(self.details),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_matches_engine_census_on_a_toy_block() {
        // main [0,100] with work [20,80] nested: exclusive main = 40,
        // work = 60 — and main is first-seen (its head segment is cut
        // when work enters).
        let mut a = CensusAccum::new();
        for ts in [0i64, 20, 80, 100] {
            a.row(ts);
        }
        a.enter(0, 0, "main");
        a.enter(0, 20, "work");
        a.leave(0, 80, "work");
        a.leave(0, 100, "main");
        a.end_block(0);
        let c = a.finish().unwrap();
        let f = c.funcs.unwrap();
        assert_eq!(f.names, vec!["main".to_string(), "work".to_string()]);
        assert_eq!(f.exc_ns, vec![40, 60]);
        assert_eq!(c.blocks.len(), 1);
        assert_eq!(c.blocks[0].rows, 4);
        assert_eq!(c.blocks[0].span, Some((0, 100)));
        assert_eq!(c.span(), Some((0, 100)));
    }

    #[test]
    fn accum_sorts_blocks_canonically_before_the_walk() {
        // events arrive in file order (thread 1 first) but the walk must
        // see the canonical (thread, ts) order
        let mut a = CensusAccum::new();
        a.enter(1, 0, "b");
        a.leave(1, 10, "b");
        a.enter(0, 0, "a");
        a.leave(0, 10, "a");
        a.row(0);
        a.end_block(7);
        let f = a.finish().unwrap().funcs.unwrap();
        // thread 0's "a" sorts first, so it is first-seen
        assert_eq!(f.names, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn channels_and_msgs_accumulate() {
        let mut a = CensusAccum::new();
        a.send(0, 1, 0, 100);
        a.send(0, 1, 0, 300);
        a.send(0, 2, 5, -7); // clamped to 0
        a.recv(1, 0, 0, 300);
        a.recv(3, crate::df::NULL_I64, 0, 999); // null partner: skipped
        a.end_block(0);
        let c = a.finish().unwrap();
        let chans = c.channels.unwrap();
        assert_eq!(chans.len(), 2);
        assert_eq!(
            chans[0],
            ChannelCensus { src: 0, dst: 1, tag: 0, sends: 2, recvs: 1 }
        );
        assert_eq!(
            chans[1],
            ChannelCensus { src: 0, dst: 2, tag: 5, sends: 1, recvs: 0 }
        );
        let m = c.msgs.unwrap();
        assert_eq!(m.max_send, 300);
        assert_eq!(m.max_recv, 300);
        assert!(m.saw_send);
    }

    #[test]
    fn block_detail_rows_sum_to_the_global_sections() {
        // two blocks: proc 0 runs main/work and sends; proc 1 only
        // receives — each block's sub-census must carry exactly its own
        // contribution, keyed by the global slots.
        let mut a = CensusAccum::new();
        a.enter(0, 0, "main");
        a.enter(0, 20, "work");
        a.leave(0, 80, "work");
        a.leave(0, 100, "main");
        a.send(0, 1, 0, 64);
        a.row(0);
        a.end_block(0);
        a.enter(0, 0, "main");
        a.leave(0, 50, "main");
        a.recv(1, 0, 0, 64);
        a.row(0);
        a.end_block(1);
        let c = a.finish().unwrap();
        let d = c.block_detail.as_ref().unwrap();
        assert_eq!(d.len(), c.blocks.len());
        // block 0: main (slot 0) = 40, work (slot 1) = 60; one send
        assert_eq!(d[0].funcs, vec![(0, 40), (1, 60)]);
        assert_eq!(d[0].channels, vec![(0, 1, 0)]);
        // block 1: main only; one recv on the same channel slot
        assert_eq!(d[1].funcs, vec![(0, 50)]);
        assert_eq!(d[1].channels, vec![(0, 0, 1)]);
        // column sums reproduce the global sections
        let f = c.funcs.unwrap();
        assert_eq!(f.exc_ns, vec![40 + 50, 60]);
        let chans = c.channels.unwrap();
        assert_eq!((chans[0].sends, chans[0].recvs), (1, 1));
    }

    #[test]
    fn frame_arena_recycles_across_streams_and_blocks() {
        // Uneven nesting on two threads across two blocks, with popped
        // frame slots recycled in between and an unmatched leave on a
        // third thread: the SoA arena must reproduce the boxed-stack
        // walk's first-seen order and totals exactly.
        let mut a = CensusAccum::new();
        a.enter(0, 0, "a");
        a.enter(0, 10, "b");
        a.enter(0, 20, "c");
        a.leave(0, 30, "c");
        a.enter(1, 5, "d");
        a.leave(1, 25, "d");
        a.leave(2, 3, "stray"); // unmatched leave: ignored
        a.end_block(0);
        // same proc: thread 0's open a/b frames persist into this block
        a.leave(0, 40, "b");
        a.leave(0, 50, "a");
        a.enter(0, 60, "e");
        a.leave(0, 65, "e");
        a.enter(1, 41, "f");
        a.enter(1, 42, "g");
        a.leave(1, 44, "g");
        a.leave(1, 45, "f");
        a.end_block(0);
        let f = a.finish().unwrap().funcs.unwrap();
        assert_eq!(f.names, ["a", "b", "c", "d", "e", "f", "g"].map(str::to_string));
        // a: [0,10]+[40,50]; b: [10,20]+[30,40]; c: [20,30]; d: [5,25];
        // e: [60,65]; f: [41,42]+[44,45]; g: [42,44]
        assert_eq!(f.exc_ns, vec![20, 20, 10, 20, 5, 2, 2]);
    }

    #[test]
    fn forfeit_discards_everything() {
        let mut a = CensusAccum::new();
        a.enter(0, 0, "main");
        a.forfeit();
        a.end_block(0);
        assert_eq!(a.finish(), None);
    }

    #[test]
    fn fnv32_is_stable_and_sensitive() {
        let h = fnv32(b"census");
        assert_eq!(h, fnv32(b"census"));
        assert_ne!(h, fnv32(b"censuX"));
        assert_ne!(fnv32(b""), fnv32(b"\0"));
    }
}
