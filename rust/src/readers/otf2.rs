//! OTF2-sim: a compact binary trace format with OTF2's *structure*.
//!
//! Real OTF2 archives split global definitions (string / region tables)
//! from per-rank event streams; that split is what makes parallel reading
//! and dictionary-encoded names possible, and it is exactly what we keep:
//!
//! ```text
//! <dir>/defs.bin      magic, app name, #ranks, region-name table
//! <dir>/rank_<r>.bin  zlib stream of records, timestamps delta-encoded
//! ```
//!
//! Record encoding (after decompression): one tag byte, then LEB128
//! varints — `Enter/Leave(region)`, `Send/Recv(partner, bytes, tag)`,
//! `Instant(region)`. Region refs index the global table, so every rank
//! shard can be decoded into dictionary codes without locking; the reader
//! decodes rank files on a thread pool ([`super::parallel_map`]) and
//! concatenates shards in rank order (paper §VI / Fig. 5 center).

use super::census::{fnv32, CensusAccum, TraceCensus, CENSUS_VERSION};
use crate::df::{Column, Interner, Table, NULL_I64};
use crate::trace::*;
use anyhow::{bail, Context, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"OTF2SIM1";

/// Marker byte introducing the optional per-rank timestamp-extrema
/// section appended after the string table. Archives written before the
/// section exist too (the checked-in fixtures): readers treat a missing
/// section as "extrema unknown", which disables the cheap span pre-scan
/// but nothing else.
const EXTREMA_MARKER: u8 = 0xE5;

/// Marker byte introducing the optional census trailing section (per-rank
/// row counts, function exclusive-time census, channel endpoint census,
/// message-size extrema), appended after the extrema section. The section
/// is length-prefixed, versioned and FNV-checksummed: a corrupt or
/// truncated section degrades to "census absent" (legacy buffering paths,
/// surfaced via `StreamStats::fallback`), never to a read error or a
/// silently wrong census.
const CENSUS_MARKER: u8 = 0xC6;

// record tags
const T_ENTER: u8 = 0;
const T_LEAVE: u8 = 1;
const T_SEND: u8 = 2;
const T_RECV: u8 = 3;
const T_INSTANT: u8 = 4;

// -- varint helpers --------------------------------------------------------

pub(crate) fn put_uvarint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let mut b = (v & 0x7f) as u8;
        v >>= 7;
        if v != 0 {
            b |= 0x80;
        }
        buf.push(b);
        if v == 0 {
            break;
        }
    }
}

#[inline]
pub(crate) fn get_uvarint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    // fast path: single-byte varints dominate real streams (region refs,
    // small deltas) — worth ~15% of total decode time (EXPERIMENTS §Perf)
    if let Some(&b) = buf.get(*pos) {
        if b & 0x80 == 0 {
            *pos += 1;
            return Ok(b as u64);
        }
    }
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf.get(*pos).context("truncated varint")?;
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint overflow");
        }
    }
}

// -- writer -----------------------------------------------------------------

/// Write `trace` as an OTF2-sim directory. Region names become the global
/// string table; each rank's events stream is delta-encoded + compressed.
pub fn write(trace: &Trace, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let ts = trace.events.i64s(COL_TS)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let ms = trace.events.i64s(COL_MSG_SIZE)?;
    let tg = trace.events.i64s(COL_TAG)?;
    let enter = edict.code_of(ENTER);
    let leave = edict.code_of(LEAVE);
    let send_name = ndict.code_of(SEND_EVENT);
    let recv_name = ndict.code_of(RECV_EVENT);

    let ranks = trace.process_ids()?;

    // Per-rank timestamp extrema in one linear pass — the cheap-span
    // section the streaming two-pass pre-scan reads so `time_profile` /
    // `comm_over_time` know the global span before decoding any shard.
    let mut rank_slot = std::collections::HashMap::with_capacity(ranks.len());
    for (k, &r) in ranks.iter().enumerate() {
        rank_slot.insert(r, k);
    }
    let mut extrema: Vec<Option<(i64, i64)>> = vec![None; ranks.len()];
    for i in 0..trace.len() {
        let slot = rank_slot[&pr[i]];
        match &mut extrema[slot] {
            Some((lo, hi)) => {
                *lo = (*lo).min(ts[i]);
                *hi = (*hi).max(ts[i]);
            }
            e => *e = Some((ts[i], ts[i])),
        }
    }

    // defs.bin
    let mut defs = Vec::new();
    defs.extend_from_slice(MAGIC);
    let app = trace.meta.app.as_bytes();
    put_uvarint(&mut defs, app.len() as u64);
    defs.extend_from_slice(app);
    put_uvarint(&mut defs, ranks.len() as u64);
    for &r in &ranks {
        put_uvarint(&mut defs, r as u64);
    }
    put_uvarint(&mut defs, ndict.len() as u64);
    for s in ndict.strings() {
        put_uvarint(&mut defs, s.len() as u64);
        defs.extend_from_slice(s.as_bytes());
    }
    defs.push(EXTREMA_MARKER);
    for e in &extrema {
        match e {
            Some((lo, hi)) => {
                defs.push(1);
                // write bails below on any ts < 0 (delta encoding), so
                // lo >= 0 and the uvarints are well-formed
                put_uvarint(&mut defs, (*lo).max(0) as u64);
                put_uvarint(&mut defs, (*hi - *lo).max(0) as u64);
            }
            None => defs.push(0),
        }
    }

    // rank_<r>.bin — events are canonically ordered so one linear pass
    // per rank suffices; the same pass feeds the census accumulator with
    // the rows exactly as the decoder will reproduce them — thread
    // flattened to 0 (rank files carry no thread ids), partner / size
    // clamped, null tags written as 0 — so the census agrees bit-for-bit
    // with the census an engine would take over the decoded trace. Rank
    // blocks feed in rank order = shard order.
    let mut accum = CensusAccum::new();
    for &r in &ranks {
        let mut raw = Vec::new();
        let mut last_ts = 0i64;
        for i in 0..trace.len() {
            if pr[i] != r {
                continue;
            }
            if ts[i] < last_ts {
                bail!("rank {r}: timestamps not monotone at row {i}");
            }
            let dt = (ts[i] - last_ts) as u64;
            last_ts = ts[i];
            accum.row(ts[i]);
            let code = Some(et[i]);
            if code == enter {
                raw.push(T_ENTER);
                put_uvarint(&mut raw, dt);
                put_uvarint(&mut raw, nm[i] as u64);
                accum.enter(0, ts[i], ndict.resolve(nm[i]).unwrap_or(""));
            } else if code == leave {
                raw.push(T_LEAVE);
                put_uvarint(&mut raw, dt);
                put_uvarint(&mut raw, nm[i] as u64);
                accum.leave(0, ts[i], ndict.resolve(nm[i]).unwrap_or(""));
            } else if Some(nm[i]) == send_name || Some(nm[i]) == recv_name {
                raw.push(if Some(nm[i]) == send_name { T_SEND } else { T_RECV });
                put_uvarint(&mut raw, dt);
                put_uvarint(&mut raw, pa[i].max(0) as u64);
                put_uvarint(&mut raw, ms[i].max(0) as u64);
                let tag = if tg[i] == NULL_I64 { 0 } else { tg[i] };
                put_uvarint(&mut raw, tag as u64);
                if Some(nm[i]) == send_name {
                    accum.send(r, pa[i].max(0), tag, ms[i].max(0));
                } else {
                    accum.recv(r, pa[i].max(0), tag, ms[i].max(0));
                }
            } else {
                raw.push(T_INSTANT);
                put_uvarint(&mut raw, dt);
                put_uvarint(&mut raw, nm[i] as u64);
            }
        }
        accum.end_block(r);
        let f = std::fs::File::create(dir.join(format!("rank_{r}.bin")))?;
        let mut enc = ZlibEncoder::new(f, Compression::fast());
        enc.write_all(&raw)?;
        enc.finish()?;
    }
    if let Some(census) = accum.finish() {
        let mut payload = Vec::new();
        put_uvarint(&mut payload, CENSUS_VERSION);
        put_uvarint(&mut payload, census.blocks.len() as u64);
        for b in &census.blocks {
            put_uvarint(&mut payload, b.rows);
        }
        // function names reference the string table just written above
        let funcs = census.funcs.as_ref().expect("writer census never forfeits");
        put_uvarint(&mut payload, funcs.names.len() as u64);
        for (name, ns) in funcs.names.iter().zip(&funcs.exc_ns) {
            let code = ndict
                .code_of(name)
                .context("census function missing from the string table")?;
            put_uvarint(&mut payload, code as u64);
            put_uvarint(&mut payload, (*ns).max(0) as u64);
        }
        let chans = census.channels.as_ref().expect("writer census never forfeits");
        put_uvarint(&mut payload, chans.len() as u64);
        for c in chans {
            // all ids are clamped non-negative above, tags null-mapped to 0
            put_uvarint(&mut payload, c.src.max(0) as u64);
            put_uvarint(&mut payload, c.dst.max(0) as u64);
            put_uvarint(&mut payload, c.tag.max(0) as u64);
            put_uvarint(&mut payload, c.sends);
            put_uvarint(&mut payload, c.recvs);
        }
        let m = census.msgs.expect("writer census never forfeits");
        payload.push(m.saw_send as u8);
        put_uvarint(&mut payload, (m.max_send + 1) as u64); // -1 (none) -> 0
        put_uvarint(&mut payload, (m.max_recv + 1) as u64);
        defs.push(CENSUS_MARKER);
        put_uvarint(&mut defs, (payload.len() + 4) as u64);
        defs.extend_from_slice(&payload);
        defs.extend_from_slice(&fnv32(&payload).to_le_bytes());
    }
    std::fs::write(dir.join("defs.bin"), defs)?;
    Ok(())
}

// -- reader -----------------------------------------------------------------

pub(crate) struct Defs {
    pub(crate) app: String,
    pub(crate) ranks: Vec<i64>,
    pub(crate) names: Arc<Interner>,
    /// Per-rank (min, max) timestamps from the extrema section; None for
    /// archives written before the section existed (span pre-scan
    /// unavailable) or for ranks with no events.
    pub(crate) extrema: Option<Vec<Option<(i64, i64)>>>,
    /// The pre-scan census from the trailing section; None for archives
    /// written before the section existed, for unknown future versions,
    /// and for corrupt sections (see `census_corrupt`).
    pub(crate) census: Option<TraceCensus>,
    /// True when a census section was present but failed its length /
    /// checksum / payload validation: consumers run their census-less
    /// legacy paths and surface the degradation instead of erroring.
    pub(crate) census_corrupt: bool,
    send_code: u32,
    recv_code: u32,
}

impl Defs {
    /// Global (min, max) timestamp over every rank, from the extrema
    /// section alone — the streaming span pre-scan. None when the
    /// archive predates the section or holds no events.
    pub(crate) fn span(&self) -> Option<(i64, i64)> {
        let ex = self.extrema.as_ref()?;
        let mut out: Option<(i64, i64)> = None;
        for &(lo, hi) in ex.iter().flatten() {
            out = Some(match out {
                Some((a, b)) => (a.min(lo), b.max(hi)),
                None => (lo, hi),
            });
        }
        out
    }
}

pub(crate) fn read_defs(dir: &Path) -> Result<Defs> {
    let buf = std::fs::read(dir.join("defs.bin"))
        .with_context(|| format!("reading {}/defs.bin", dir.display()))?;
    if buf.len() < 8 || &buf[..8] != MAGIC {
        bail!("bad OTF2-sim magic in {}", dir.display());
    }
    let mut pos = 8usize;
    // bounds-checked slice: truncated defs must error, not panic
    let take = |pos: &mut usize, len: usize| -> Result<&[u8]> {
        let end = pos.checked_add(len).context("defs.bin length overflow")?;
        if end > buf.len() {
            bail!("defs.bin truncated at byte {pos}");
        }
        let out = &buf[*pos..end];
        *pos = end;
        Ok(out)
    };
    let app_len = get_uvarint(&buf, &mut pos)? as usize;
    let app = String::from_utf8(take(&mut pos, app_len)?.to_vec())?;
    let nranks = get_uvarint(&buf, &mut pos)? as usize;
    if nranks > 10_000_000 {
        bail!("defs.bin declares an implausible rank count {nranks}");
    }
    let mut ranks = Vec::with_capacity(nranks);
    for _ in 0..nranks {
        ranks.push(get_uvarint(&buf, &mut pos)? as i64);
    }
    let nstr = get_uvarint(&buf, &mut pos)? as usize;
    if nstr > 100_000_000 {
        bail!("defs.bin declares an implausible string count {nstr}");
    }
    let mut names = Interner::new();
    for _ in 0..nstr {
        let len = get_uvarint(&buf, &mut pos)? as usize;
        let s = std::str::from_utf8(take(&mut pos, len)?)?;
        names.intern(s);
    }
    // optional trailing extrema section (absent in older archives)
    let extrema = if pos < buf.len() {
        if buf[pos] != EXTREMA_MARKER {
            bail!("defs.bin: unknown trailing section byte {:#x}", buf[pos]);
        }
        pos += 1;
        let mut ex = Vec::with_capacity(nranks);
        for _ in 0..nranks {
            let flag = *buf.get(pos).context("defs.bin truncated in extrema section")?;
            pos += 1;
            ex.push(match flag {
                0 => None,
                1 => {
                    let lo = get_uvarint(&buf, &mut pos)? as i64;
                    let width = get_uvarint(&buf, &mut pos)? as i64;
                    Some((lo, lo + width))
                }
                other => bail!("defs.bin: bad extrema flag {other}"),
            });
        }
        Some(ex)
    } else {
        None
    };
    // optional census trailing section: strictly lenient — whatever goes
    // wrong past this point degrades to census-absent (flagged), never to
    // a read error, so a damaged trailer can't take the archive down
    let (census, census_corrupt) = if pos < buf.len() {
        parse_census_section(&buf, pos, nranks, &names, &extrema)
    } else {
        (None, false)
    };
    // ensure message event names exist even in traces without messages
    let send_code = names.intern(SEND_EVENT);
    let recv_code = names.intern(RECV_EVENT);
    Ok(Defs {
        app,
        ranks,
        names: Arc::new(names),
        extrema,
        census,
        census_corrupt,
        send_code,
        recv_code,
    })
}

/// Parse the census trailing section starting at `pos` (at its marker
/// byte). Returns `(census, corrupt)`: `(None, true)` for any anomaly —
/// wrong marker, truncated length, checksum mismatch, malformed payload —
/// and `(None, false)` only for an intact section of an unknown future
/// version (forward compatibility, not damage).
fn parse_census_section(
    buf: &[u8],
    mut pos: usize,
    nranks: usize,
    names: &Interner,
    extrema: &Option<Vec<Option<(i64, i64)>>>,
) -> (Option<TraceCensus>, bool) {
    let corrupt = (None, true);
    if buf[pos] != CENSUS_MARKER {
        return corrupt;
    }
    pos += 1;
    let Ok(len) = get_uvarint(buf, &mut pos) else { return corrupt };
    let Some(end) = pos.checked_add(len as usize) else { return corrupt };
    if end > buf.len() || len < 4 {
        return corrupt;
    }
    let body_end = end - 4;
    let want = u32::from_le_bytes([
        buf[body_end],
        buf[body_end + 1],
        buf[body_end + 2],
        buf[body_end + 3],
    ]);
    if fnv32(&buf[pos..body_end]) != want {
        return corrupt;
    }
    // checksum holds: parse the payload strictly within [pos, body_end)
    let body = &buf[..body_end];
    let mut p = pos;
    let parsed = (|| -> Result<Option<TraceCensus>> {
        let version = get_uvarint(body, &mut p)?;
        if version != CENSUS_VERSION {
            return Ok(None); // future version: intact but unknown
        }
        let nblocks = get_uvarint(body, &mut p)? as usize;
        if nblocks != nranks {
            bail!("census block count disagrees with rank count");
        }
        let mut blocks = Vec::with_capacity(nblocks);
        for i in 0..nblocks {
            let rows = get_uvarint(body, &mut p)?;
            let span = extrema.as_ref().and_then(|ex| ex.get(i).copied().flatten());
            blocks.push(super::census::BlockCensus { rows, span });
        }
        let nfuncs = get_uvarint(body, &mut p)? as usize;
        if nfuncs > names.len() {
            bail!("census function count exceeds the string table");
        }
        let mut fnames = Vec::with_capacity(nfuncs);
        let mut exc_ns = Vec::with_capacity(nfuncs);
        for _ in 0..nfuncs {
            let code = get_uvarint(body, &mut p)? as u32;
            let name = names
                .resolve(code)
                .context("census function ref out of range")?;
            fnames.push(name.to_string());
            exc_ns.push(get_uvarint(body, &mut p)? as i64);
        }
        let nchans = get_uvarint(body, &mut p)? as usize;
        if nchans > 100_000_000 {
            bail!("implausible census channel count");
        }
        let mut channels = Vec::with_capacity(nchans);
        for _ in 0..nchans {
            let src = get_uvarint(body, &mut p)? as i64;
            let dst = get_uvarint(body, &mut p)? as i64;
            let tag = get_uvarint(body, &mut p)? as i64;
            let sends = get_uvarint(body, &mut p)?;
            let recvs = get_uvarint(body, &mut p)?;
            channels.push(super::census::ChannelCensus { src, dst, tag, sends, recvs });
        }
        let saw_send = match body.get(p) {
            Some(0) => false,
            Some(1) => true,
            _ => bail!("bad census saw_send flag"),
        };
        p += 1;
        let max_send = get_uvarint(body, &mut p)? as i64 - 1;
        let max_recv = get_uvarint(body, &mut p)? as i64 - 1;
        if p != body_end {
            bail!("census payload has trailing bytes");
        }
        Ok(Some(TraceCensus {
            version,
            blocks,
            funcs: Some(super::census::FuncTotals { names: fnames, exc_ns }),
            channels: Some(channels),
            msgs: Some(super::census::MsgCensus { max_send, max_recv, saw_send }),
            // the defs trailer predates per-block sub-censuses; the
            // archive format is the carrier for those
            block_detail: None,
        }))
    })();
    match parsed {
        Ok(Some(c)) => (Some(c), false),
        Ok(None) => (None, false),
        Err(_) => corrupt,
    }
}

/// Columnar shard for one rank (already in canonical order).
pub(crate) struct Shard {
    ts: Vec<i64>,
    et: Vec<u32>,
    nm: Vec<u32>,
    pr: Vec<i64>,
    pa: Vec<i64>,
    ms: Vec<i64>,
    tg: Vec<i64>,
}

pub(crate) fn read_rank(dir: &Path, rank: i64, defs: &Defs, etypes: &EtypeCodes) -> Result<Shard> {
    decode_rank(&rank_bytes(dir, rank)?, rank, defs, etypes)
}

/// The raw (still-compressed) bytes of one rank stream — the pure-I/O
/// half of a shard read, which the pipelined streaming driver runs on
/// its own thread before handing [`decode_rank`] to a worker.
pub(crate) fn rank_bytes(dir: &Path, rank: i64) -> Result<Vec<u8>> {
    let p = dir.join(format!("rank_{rank}.bin"));
    std::fs::read(&p).with_context(|| format!("reading {}", p.display()))
}

/// Decompress + parse one rank stream from its raw file bytes — the
/// CPU half of a shard read, safe to run on any thread (all shared
/// state is behind `Arc`s in `defs`).
pub(crate) fn decode_rank(
    data: &[u8],
    rank: i64,
    defs: &Defs,
    etypes: &EtypeCodes,
) -> Result<Shard> {
    let mut raw = Vec::new();
    ZlibDecoder::new(data).read_to_end(&mut raw)?;
    let mut pos = 0usize;
    // enter/leave records are >= 3 bytes, so raw.len() / 3 upper-bounds
    // the event count — pre-reserving avoids growth reallocations.
    let cap = raw.len() / 3 + 1;
    let mut sh = Shard {
        ts: Vec::with_capacity(cap),
        et: Vec::with_capacity(cap),
        nm: Vec::with_capacity(cap),
        pr: Vec::with_capacity(cap),
        pa: Vec::with_capacity(cap),
        ms: Vec::with_capacity(cap),
        tg: Vec::with_capacity(cap),
    };
    let mut t = 0i64;
    let nname = defs.names.len() as u64;
    while pos < raw.len() {
        let tag = raw[pos];
        pos += 1;
        t += get_uvarint(&raw, &mut pos)? as i64;
        match tag {
            T_ENTER | T_LEAVE | T_INSTANT => {
                let region = get_uvarint(&raw, &mut pos)?;
                if region >= nname {
                    bail!("rank {rank}: region ref {region} out of range");
                }
                sh.ts.push(t);
                sh.et.push(match tag {
                    T_ENTER => etypes.enter,
                    T_LEAVE => etypes.leave,
                    _ => etypes.instant,
                });
                sh.nm.push(region as u32);
                sh.pa.push(NULL_I64);
                sh.ms.push(NULL_I64);
                sh.tg.push(NULL_I64);
            }
            T_SEND | T_RECV => {
                let partner = get_uvarint(&raw, &mut pos)? as i64;
                let bytes = get_uvarint(&raw, &mut pos)? as i64;
                let tagv = get_uvarint(&raw, &mut pos)? as i64;
                sh.ts.push(t);
                sh.et.push(etypes.instant);
                sh.nm
                    .push(if tag == T_SEND { defs.send_code } else { defs.recv_code });
                sh.pa.push(partner);
                sh.ms.push(bytes);
                sh.tg.push(tagv);
            }
            other => bail!("rank {rank}: unknown record tag {other}"),
        }
        sh.pr.push(rank);
    }
    Ok(sh)
}

#[derive(Clone, Copy)]
pub(crate) struct EtypeCodes {
    enter: u32,
    leave: u32,
    instant: u32,
}

/// The `Event Type` dictionary (Enter/Leave/Instant) plus its codes —
/// shared by the eager reader and the streaming reader so shards carry
/// identical event-type encodings.
pub(crate) fn etype_codes() -> (Arc<Interner>, EtypeCodes) {
    let mut etype_dict = Interner::new();
    let etypes = EtypeCodes {
        enter: etype_dict.intern(ENTER),
        leave: etype_dict.intern(LEAVE),
        instant: etype_dict.intern(INSTANT),
    };
    (Arc::new(etype_dict), etypes)
}

/// Assemble one decoded rank shard into a canonical events table. The
/// name / event-type dictionaries are shared (`Arc`), so codes resolve
/// identically across every shard of the same archive.
pub(crate) fn shard_table(
    sh: Shard,
    names: &Arc<Interner>,
    etype_dict: &Arc<Interner>,
) -> Result<Table> {
    let n = sh.ts.len();
    let mut table = Table::new();
    table.push(COL_TS, Column::I64(sh.ts))?;
    table.push(COL_TYPE, Column::Str { codes: sh.et, dict: Arc::clone(etype_dict) })?;
    table.push(COL_NAME, Column::Str { codes: sh.nm, dict: Arc::clone(names) })?;
    table.push(COL_PROC, Column::I64(sh.pr))?;
    table.push(COL_THREAD, Column::I64(vec![0; n]))?;
    table.push(COL_PARTNER, Column::I64(sh.pa))?;
    table.push(COL_MSG_SIZE, Column::I64(sh.ms))?;
    table.push(COL_TAG, Column::I64(sh.tg))?;
    Ok(table)
}

/// Read an OTF2-sim directory with `threads` reader threads (0 = all
/// cores). Rank shards decode independently and concatenate in rank order,
/// so the result is canonically sorted without a global sort.
pub fn read(dir: &Path, threads: usize) -> Result<Trace> {
    let defs = read_defs(dir)?;
    let (etype_dict, etypes) = etype_codes();

    let shards = super::parallel_map(defs.ranks.len(), threads, |i| {
        read_rank(dir, defs.ranks[i], &defs, &etypes)
    })?;

    let total: usize = shards.iter().map(|s| s.ts.len()).sum();
    let mut ts = Vec::with_capacity(total);
    let mut et = Vec::with_capacity(total);
    let mut nm = Vec::with_capacity(total);
    let mut pr = Vec::with_capacity(total);
    let mut pa = Vec::with_capacity(total);
    let mut ms = Vec::with_capacity(total);
    let mut tg = Vec::with_capacity(total);
    for mut s in shards {
        ts.append(&mut s.ts);
        et.append(&mut s.et);
        nm.append(&mut s.nm);
        pr.append(&mut s.pr);
        pa.append(&mut s.pa);
        ms.append(&mut s.ms);
        tg.append(&mut s.tg);
    }
    let n = ts.len();
    let mut table = Table::new();
    table.push(COL_TS, Column::I64(ts))?;
    table.push(COL_TYPE, Column::Str { codes: et, dict: etype_dict })?;
    table.push(COL_NAME, Column::Str { codes: nm, dict: Arc::clone(&defs.names) })?;
    table.push(COL_PROC, Column::I64(pr))?;
    table.push(COL_THREAD, Column::I64(vec![0; n]))?;
    table.push(COL_PARTNER, Column::I64(pa))?;
    table.push(COL_MSG_SIZE, Column::I64(ms))?;
    table.push(COL_TAG, Column::I64(tg))?;
    Ok(Trace::new(
        table,
        TraceMeta {
            format: "otf2".into(),
            source: dir.display().to_string(),
            app: defs.app,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::validate_nesting;

    fn sample(nranks: i64, iters: usize) -> Trace {
        let mut b = TraceBuilder::new();
        b.set_meta(TraceMeta { app: "toy".into(), ..Default::default() });
        for r in 0..nranks {
            let mut t = 0;
            b.enter(r, 0, t, "main");
            for _ in 0..iters {
                t += 10;
                b.enter(r, 0, t, "compute");
                t += 50;
                b.leave(r, 0, t, "compute");
                t += 5;
                b.enter(r, 0, t, "MPI_Send");
                b.send(r, 0, t + 1, (r + 1) % nranks, 4096, 0);
                t += 10;
                b.leave(r, 0, t, "MPI_Send");
            }
            b.leave(r, 0, t + 10, "main");
        }
        b.finish()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pipit_otf2_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn roundtrip_lossless() {
        let t = sample(4, 5);
        let dir = tmp("rt");
        write(&t, &dir).unwrap();
        let t2 = read(&dir, 1).unwrap();
        assert_eq!(t2.len(), t.len());
        assert_eq!(t2.meta.app, "toy");
        assert_eq!(t2.timestamps().unwrap(), t.timestamps().unwrap());
        assert_eq!(t2.processes().unwrap(), t.processes().unwrap());
        assert_eq!(
            t2.events.i64s(COL_MSG_SIZE).unwrap(),
            t.events.i64s(COL_MSG_SIZE).unwrap()
        );
        // names resolve identically row by row
        let (nm1, d1) = t.events.strs(COL_NAME).unwrap();
        let (nm2, d2) = t2.events.strs(COL_NAME).unwrap();
        for i in 0..t.len() {
            assert_eq!(d1.resolve(nm1[i]), d2.resolve(nm2[i]), "row {i}");
        }
        validate_nesting(&t2).unwrap();
    }

    #[test]
    fn parallel_read_matches_serial() {
        let t = sample(8, 20);
        let dir = tmp("par");
        write(&t, &dir).unwrap();
        let serial = read(&dir, 1).unwrap();
        let parallel = read(&dir, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        assert_eq!(serial.timestamps().unwrap(), parallel.timestamps().unwrap());
        assert_eq!(serial.processes().unwrap(), parallel.processes().unwrap());
    }

    #[test]
    fn defs_extrema_give_the_global_span() {
        let t = sample(4, 5);
        let dir = tmp("span");
        write(&t, &dir).unwrap();
        let defs = read_defs(&dir).unwrap();
        assert!(defs.extrema.is_some());
        assert_eq!(defs.span(), Some(t.time_range().unwrap()));
    }

    #[test]
    fn rejects_corrupt_magic() {
        let dir = tmp("bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("defs.bin"), b"NOTOTF2!xxxx").unwrap();
        assert!(read(&dir, 1).is_err());
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX];
        for &v in &vals {
            put_uvarint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }
}
