//! Projections-sim: Charm++-style text logs.
//!
//! Layout mirrors real Projections output: one `<app>.sts` summary file
//! declaring the entry-method table, plus one `<app>.<pe>.log` text file
//! per PE. Log record verbs (a compatible subset of the Projections
//! grammar):
//!
//! ```text
//! BEGIN_PROCESSING <ep> <time>
//! END_PROCESSING <ep> <time>
//! CREATION <ep> <time> <destPE> <bytes>     (message send)
//! BEGIN_IDLE <time>
//! END_IDLE <time>
//! ```
//!
//! `BEGIN/END_IDLE` become Enter/Leave of the synthetic `Idle` function —
//! Projections is the one tool in the paper's survey that records idleness
//! explicitly (the Loimos case studies, Figs. 7/9, rely on it).
//! Per-PE logs parse independently on a thread pool.

use crate::trace::*;
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Read a Projections-sim directory with `threads` reader threads.
pub fn read(dir: &Path, threads: usize) -> Result<Trace> {
    let sts = find_sts(dir)?;
    let stem = sts
        .file_stem()
        .and_then(|s| s.to_str())
        .context("bad .sts name")?
        .to_string();
    let text = std::fs::read_to_string(&sts)?;
    let mut npes = 0usize;
    let mut eps: Vec<String> = Vec::new();
    for line in text.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("PROCESSORS") => {
                npes = it.next().context("PROCESSORS missing count")?.parse()?
            }
            Some("ENTRY") => {
                let id: usize = it.next().context("ENTRY missing id")?.parse()?;
                // name is the rest of the line (may contain spaces)
                let name = line
                    .splitn(3, char::is_whitespace)
                    .nth(2)
                    .unwrap_or("")
                    .trim()
                    .to_string();
                if eps.len() <= id {
                    eps.resize(id + 1, String::new());
                }
                eps[id] = name;
            }
            _ => {}
        }
    }
    if npes == 0 {
        bail!("{}: no PROCESSORS line", sts.display());
    }

    // Parse each PE log independently, then merge through one builder so
    // all shards share a single dictionary.
    let logs = super::parallel_map(npes, threads, |pe| {
        let path = dir.join(format!("{stem}.{pe}.log"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        parse_pe_log(&text, pe as i64, &eps)
    })?;

    let mut b = TraceBuilder::with_capacity(logs.iter().map(Vec::len).sum());
    b.set_meta(TraceMeta {
        format: "projections".into(),
        source: dir.display().to_string(),
        app: stem.clone(),
    });
    for recs in logs {
        for r in recs {
            match r {
                Rec::Enter(pe, t, name_idx) => b.enter(pe, 0, t, ep_name(&eps, name_idx)),
                Rec::Leave(pe, t, name_idx) => b.leave(pe, 0, t, ep_name(&eps, name_idx)),
                Rec::EnterIdle(pe, t) => b.enter(pe, 0, t, "Idle"),
                Rec::LeaveIdle(pe, t) => b.leave(pe, 0, t, "Idle"),
                Rec::Send(pe, t, dest, bytes) => b.send(pe, 0, t, dest, bytes, 0),
            }
        }
    }
    Ok(b.finish())
}

fn ep_name<'a>(eps: &'a [String], i: usize) -> &'a str {
    eps.get(i).map(|s| s.as_str()).filter(|s| !s.is_empty()).unwrap_or("<unknown-ep>")
}

enum Rec {
    Enter(i64, i64, usize),
    Leave(i64, i64, usize),
    EnterIdle(i64, i64),
    LeaveIdle(i64, i64),
    Send(i64, i64, i64, i64),
}

fn parse_pe_log(text: &str, pe: i64, eps: &[String]) -> Result<Vec<Rec>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let verb = it.next().unwrap();
        let mut next_i64 = || -> Result<i64> {
            it.next()
                .with_context(|| format!("pe {pe} line {}: missing field", lineno + 1))?
                .parse::<i64>()
                .with_context(|| format!("pe {pe} line {}: bad integer", lineno + 1))
        };
        match verb {
            "BEGIN_PROCESSING" => {
                let ep = next_i64()? as usize;
                let t = next_i64()?;
                if ep >= eps.len() {
                    bail!("pe {pe} line {}: entry {ep} undefined", lineno + 1);
                }
                out.push(Rec::Enter(pe, t, ep));
            }
            "END_PROCESSING" => {
                let ep = next_i64()? as usize;
                let t = next_i64()?;
                out.push(Rec::Leave(pe, t, ep));
            }
            "BEGIN_IDLE" => out.push(Rec::EnterIdle(pe, next_i64()?)),
            "END_IDLE" => out.push(Rec::LeaveIdle(pe, next_i64()?)),
            "CREATION" => {
                let _ep = next_i64()?;
                let t = next_i64()?;
                let dest = next_i64()?;
                let bytes = next_i64()?;
                out.push(Rec::Send(pe, t, dest, bytes));
            }
            other => bail!("pe {pe} line {}: unknown verb '{other}'", lineno + 1),
        }
    }
    Ok(out)
}

fn find_sts(dir: &Path) -> Result<PathBuf> {
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?
    {
        let p = entry?.path();
        if p.extension().and_then(|e| e.to_str()) == Some("sts") {
            return Ok(p);
        }
    }
    bail!("no .sts file in {}", dir.display())
}

/// Write `trace` as a Projections-sim directory (inverse of [`read`]).
/// Function names become ENTRY declarations; `Idle` maps to BEGIN/END_IDLE.
pub fn write(trace: &Trace, dir: &Path, app: &str) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let ts = trace.events.i64s(COL_TS)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let ms = trace.events.i64s(COL_MSG_SIZE)?;
    let enter = edict.code_of(ENTER);
    let leave = edict.code_of(LEAVE);
    let send = ndict.code_of(SEND_EVENT);
    let idle = ndict.code_of("Idle");

    let ranks = trace.process_ids()?;
    let mut sts = String::new();
    writeln!(sts, "VERSION 1.0")?;
    writeln!(sts, "PROCESSORS {}", ranks.len())?;
    for (i, name) in ndict.strings().iter().enumerate() {
        writeln!(sts, "ENTRY {i} {name}")?;
    }
    std::fs::write(dir.join(format!("{app}.sts")), sts)?;

    for (pe_idx, &r) in ranks.iter().enumerate() {
        let mut log = String::new();
        for i in 0..trace.len() {
            if pr[i] != r {
                continue;
            }
            let code = Some(et[i]);
            if code == enter {
                if Some(nm[i]) == idle {
                    writeln!(log, "BEGIN_IDLE {}", ts[i])?;
                } else {
                    writeln!(log, "BEGIN_PROCESSING {} {}", nm[i], ts[i])?;
                }
            } else if code == leave {
                if Some(nm[i]) == idle {
                    writeln!(log, "END_IDLE {}", ts[i])?;
                } else {
                    writeln!(log, "END_PROCESSING {} {}", nm[i], ts[i])?;
                }
            } else if Some(nm[i]) == send {
                writeln!(
                    log,
                    "CREATION {} {} {} {}",
                    nm[i],
                    ts[i],
                    pa[i].max(0),
                    ms[i].max(0)
                )?;
            }
            // RECV instants are not representable in Projections logs
            // (Charm++ is message-driven); they are dropped on write.
        }
        std::fs::write(dir.join(format!("{app}.{pe_idx}.log")), log)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::validate_nesting;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pipit_proj_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn reads_hand_written_logs() {
        let dir = tmp("hand");
        std::fs::write(
            dir.join("app.sts"),
            "VERSION 1.0\nPROCESSORS 2\nENTRY 0 ComputeInteractions()\nENTRY 1 SendVisitMessages()\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("app.0.log"),
            "BEGIN_PROCESSING 0 100\nCREATION 1 150 1 2048\nEND_PROCESSING 0 200\nBEGIN_IDLE 200\nEND_IDLE 300\n",
        )
        .unwrap();
        std::fs::write(dir.join("app.1.log"), "BEGIN_PROCESSING 1 0\nEND_PROCESSING 1 50\n")
            .unwrap();
        let t = read(&dir, 1).unwrap();
        assert_eq!(t.num_processes().unwrap(), 2);
        validate_nesting(&t).unwrap();
        // Idle became a function; CREATION became a send instant
        let (nm, d) = t.events.strs(COL_NAME).unwrap();
        let names: Vec<&str> = nm.iter().map(|&c| d.resolve(c).unwrap()).collect();
        assert!(names.contains(&"Idle"));
        assert!(names.contains(&SEND_EVENT));
        assert!(names.contains(&"ComputeInteractions()"));
    }

    #[test]
    fn roundtrip() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "Work()");
        b.send(0, 0, 5, 1, 128, 0);
        b.leave(0, 0, 10, "Work()");
        b.enter(0, 0, 10, "Idle");
        b.leave(0, 0, 30, "Idle");
        b.enter(1, 0, 0, "Work()");
        b.leave(1, 0, 25, "Work()");
        let t = b.finish();
        let dir = tmp("rt");
        write(&t, &dir, "loimos").unwrap();
        let t2 = read(&dir, 2).unwrap();
        assert_eq!(t2.len(), t.len());
        assert_eq!(t2.timestamps().unwrap(), t.timestamps().unwrap());
        assert_eq!(t2.meta.app, "loimos");
        validate_nesting(&t2).unwrap();
    }

    #[test]
    fn parallel_matches_serial() {
        let mut b = TraceBuilder::new();
        for pe in 0..6 {
            for k in 0..10 {
                b.enter(pe, 0, k * 100, "Step()");
                b.leave(pe, 0, k * 100 + 60, "Step()");
            }
        }
        let t = b.finish();
        let dir = tmp("par");
        write(&t, &dir, "x").unwrap();
        let a = read(&dir, 1).unwrap();
        let c = read(&dir, 4).unwrap();
        assert_eq!(a.timestamps().unwrap(), c.timestamps().unwrap());
    }

    #[test]
    fn rejects_undefined_entry() {
        let dir = tmp("bad");
        std::fs::write(dir.join("a.sts"), "PROCESSORS 1\nENTRY 0 f\n").unwrap();
        std::fs::write(dir.join("a.0.log"), "BEGIN_PROCESSING 9 0\n").unwrap();
        assert!(read(&dir, 1).is_err());
    }
}
