//! HPCToolkit-sim: call-path sample databases.
//!
//! HPCToolkit traces are *not* enter/leave streams — they are per-rank
//! sequences of (timestamp, calling-context-node) samples plus a metadata
//! file describing the calling-context tree. Reconstructing enter/leave
//! events from consecutive call-path samples (pop to the common ancestor,
//! push down to the new leaf) is the real algorithmic work of an
//! HPCToolkit reader, and it is implemented here faithfully.
//!
//! Layout:
//! ```text
//! <dir>/meta.db    text: "NODE <id> <parent-id|-1> <name>" per line
//! <dir>/trace.db   text: "SAMPLE <rank> <time_ns> <node-id>" per line
//! ```

use crate::trace::*;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::path::Path;

/// A calling-context tree from meta.db.
#[derive(Debug, Default)]
pub struct MetaCct {
    /// node id -> (parent id or -1, name)
    pub nodes: HashMap<i64, (i64, String)>,
}

impl MetaCct {
    /// Root-to-node path of names (ids) for a node.
    pub fn path(&self, mut id: i64) -> Result<Vec<i64>> {
        let mut path = Vec::new();
        let mut guard = 0;
        while id != -1 {
            path.push(id);
            id = self
                .nodes
                .get(&id)
                .with_context(|| format!("cct node {id} undefined"))?
                .0;
            guard += 1;
            if guard > 10_000 {
                bail!("cct cycle detected at node {id}");
            }
        }
        path.reverse();
        Ok(path)
    }

    pub fn name(&self, id: i64) -> &str {
        self.nodes.get(&id).map(|(_, n)| n.as_str()).unwrap_or("<unknown>")
    }
}

/// Read an HPCToolkit-sim database directory.
pub fn read(dir: &Path) -> Result<Trace> {
    let meta_text = std::fs::read_to_string(dir.join("meta.db"))
        .with_context(|| format!("reading {}/meta.db", dir.display()))?;
    let mut cct = MetaCct::default();
    for (lineno, line) in meta_text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        if it.next() != Some("NODE") {
            bail!("meta.db line {}: expected NODE", lineno + 1);
        }
        let id: i64 = it.next().context("NODE missing id")?.parse()?;
        let parent: i64 = it.next().context("NODE missing parent")?.parse()?;
        let name = line.splitn(4, char::is_whitespace).nth(3).unwrap_or("").trim();
        if name.is_empty() {
            bail!("meta.db line {}: empty node name", lineno + 1);
        }
        cct.nodes.insert(id, (parent, name.to_string()));
    }

    // samples per rank, in file order (must be time-sorted per rank)
    let trace_text = std::fs::read_to_string(dir.join("trace.db"))
        .with_context(|| format!("reading {}/trace.db", dir.display()))?;
    let mut samples: HashMap<i64, Vec<(i64, i64)>> = HashMap::new();
    for (lineno, line) in trace_text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        if it.next() != Some("SAMPLE") {
            bail!("trace.db line {}: expected SAMPLE", lineno + 1);
        }
        let rank: i64 = it.next().context("missing rank")?.parse()?;
        let t: i64 = it.next().context("missing time")?.parse()?;
        let node: i64 = it.next().context("missing node")?.parse()?;
        samples.entry(rank).or_default().push((t, node));
    }

    let mut ranks: Vec<i64> = samples.keys().copied().collect();
    ranks.sort_unstable();

    let mut b = TraceBuilder::new();
    b.set_meta(TraceMeta {
        format: "hpctoolkit".into(),
        source: dir.display().to_string(),
        app: String::new(),
    });
    for &r in &ranks {
        let ss = &samples[&r];
        // current call path, root-first, as node ids
        let mut cur: Vec<i64> = Vec::new();
        let mut last_t = 0i64;
        for &(t, node) in ss {
            if t < last_t {
                bail!("rank {r}: samples not time-sorted");
            }
            last_t = t;
            let path = cct.path(node)?;
            // common prefix length
            let mut k = 0;
            while k < cur.len() && k < path.len() && cur[k] == path[k] {
                k += 1;
            }
            // pop frames no longer on the path (deepest first)
            for &id in cur[k..].iter().rev() {
                b.leave(r, 0, t, cct.name(id));
            }
            // push new frames (shallowest first)
            for &id in &path[k..] {
                b.enter(r, 0, t, cct.name(id));
            }
            cur = path;
        }
        // close remaining frames at the last sample time
        for &id in cur.iter().rev() {
            b.leave(r, 0, last_t, cct.name(id));
        }
    }
    Ok(b.finish())
}

/// Write an HPCToolkit-sim database: a CCT plus per-rank call-path samples.
/// `samples[rank]` = time-sorted (time, node-id) pairs.
pub fn write(
    dir: &Path,
    cct: &[(i64, i64, &str)],
    samples: &HashMap<i64, Vec<(i64, i64)>>,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut meta = String::new();
    for (id, parent, name) in cct {
        writeln!(meta, "NODE {id} {parent} {name}")?;
    }
    std::fs::write(dir.join("meta.db"), meta)?;
    let mut tr = String::new();
    let mut ranks: Vec<&i64> = samples.keys().collect();
    ranks.sort();
    for r in ranks {
        for (t, node) in &samples[r] {
            writeln!(tr, "SAMPLE {r} {t} {node}")?;
        }
    }
    std::fs::write(dir.join("trace.db"), tr)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::validate_nesting;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pipit_hpct_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// CCT:  main(1) -> solve(2) -> {mpi_wait(3)}, main -> io(4)
    fn sample_db(dir: &Path) {
        let cct = vec![
            (1, -1, "main"),
            (2, 1, "solve"),
            (3, 2, "MPI_Wait"),
            (4, 1, "io"),
        ];
        let mut samples = HashMap::new();
        samples.insert(
            0i64,
            vec![(0, 1), (10, 2), (20, 3), (30, 3), (40, 2), (50, 4), (60, 1)],
        );
        samples.insert(1i64, vec![(0, 1), (15, 2), (55, 1)]);
        write(dir, &cct, &samples).unwrap();
    }

    #[test]
    fn reconstructs_balanced_enter_leave() {
        let dir = tmp("basic");
        sample_db(&dir);
        let t = read(&dir).unwrap();
        validate_nesting(&t).unwrap();
        assert_eq!(t.num_processes().unwrap(), 2);
        // rank 0: main enters at 0, leaves at 60 (last sample)
        let pr = t.processes().unwrap();
        let ts = t.timestamps().unwrap();
        let (et, ed) = t.events.strs(COL_TYPE).unwrap();
        let (nm, nd) = t.events.strs(COL_NAME).unwrap();
        let rows: Vec<usize> = (0..t.len()).filter(|&i| pr[i] == 0).collect();
        let first = rows[0];
        let last = *rows.last().unwrap();
        assert_eq!(ed.resolve(et[first]), Some(ENTER));
        assert_eq!(nd.resolve(nm[first]), Some("main"));
        assert_eq!(ts[first], 0);
        assert_eq!(ed.resolve(et[last]), Some(LEAVE));
        assert_eq!(nd.resolve(nm[last]), Some("main"));
        assert_eq!(ts[last], 60);
    }

    #[test]
    fn call_path_transitions() {
        let dir = tmp("trans");
        sample_db(&dir);
        let t = read(&dir).unwrap();
        // On rank 0, between sample (40, solve) and (50, io) the reader must
        // emit Leave solve then Enter io, both at t=50.
        let pr = t.processes().unwrap();
        let ts = t.timestamps().unwrap();
        let (et, ed) = t.events.strs(COL_TYPE).unwrap();
        let (nm, nd) = t.events.strs(COL_NAME).unwrap();
        let mut saw_leave_solve = false;
        let mut saw_enter_io = false;
        for i in 0..t.len() {
            if pr[i] == 0 && ts[i] == 50 {
                let e = ed.resolve(et[i]).unwrap();
                let n = nd.resolve(nm[i]).unwrap();
                if e == LEAVE && n == "solve" {
                    saw_leave_solve = true;
                }
                if e == ENTER && n == "io" {
                    assert!(saw_leave_solve, "leave must precede enter");
                    saw_enter_io = true;
                }
            }
        }
        assert!(saw_leave_solve && saw_enter_io);
    }

    #[test]
    fn rejects_unsorted_samples() {
        let dir = tmp("unsorted");
        let cct = vec![(1, -1, "main")];
        let mut samples = HashMap::new();
        samples.insert(0i64, vec![(10, 1), (5, 1)]);
        write(&dir, &cct, &samples).unwrap();
        assert!(read(&dir).is_err());
    }

    #[test]
    fn rejects_undefined_node() {
        let dir = tmp("undef");
        let cct = vec![(1, -1, "main")];
        let mut samples = HashMap::new();
        samples.insert(0i64, vec![(0, 99)]);
        write(&dir, &cct, &samples).unwrap();
        assert!(read(&dir).is_err());
    }
}
