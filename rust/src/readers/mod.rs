//! Format readers (and writers) — one per trace ecosystem.
//!
//! | module        | format                                                    |
//! |---------------|-----------------------------------------------------------|
//! | [`csv`]       | plain CSV (paper Fig. 1)                                  |
//! | [`chrome`]    | Chrome Trace Viewer JSON (Nsight Systems, PyTorch)        |
//! | [`otf2`]      | OTF2-sim: per-rank compressed binary streams + global defs|
//! | [`projections`] | Projections-sim: Charm++-style .sts header + per-PE logs|
//! | [`hpctoolkit`]| HPCToolkit-sim: CCT metadata + per-rank call-path samples |
//! | [`archive`]   | Pipit archive: indexed compressed blocks + embedded census|
//!
//! Each reader parses into the uniform schema of [`crate::trace`]; each
//! writer emits what the paired reader parses (used by the synthetic app
//! models in [`crate::gen`] and by round-trip tests). The heavyweight
//! per-rank formats (OTF2, Projections) read their rank streams in
//! parallel (paper §VI, Fig. 5 center).
//!
//! On top of the eager readers, [`streaming`] provides shard-at-a-time
//! ingest: [`open_sharded`] yields process-aligned [`TraceShard`]s
//! incrementally so the streaming analysis driver
//! ([`crate::exec::stream`]) runs in memory bounded per shard instead of
//! per trace. The streamability pre-scans also produce a [`TraceCensus`]
//! ([`census`]): per-block metadata, a function exclusive-time census,
//! a channel endpoint census and message extrema, known before any
//! shard decodes — what lets the streamed analyses bin top-k directly
//! and pair-and-drain message channels during ingest.

pub mod archive;
pub mod census;
pub mod chrome;
pub mod csv;
pub mod hpctoolkit;
pub mod otf2;
pub mod projections;
pub mod streaming;

pub use archive::{describe as describe_archive, ArchiveBlocks, ArchiveSummary, VersionMismatch};
pub use census::{BlockCensus, BlockDetail, ChannelCensus, FuncTotals, MsgCensus, TraceCensus};
pub use streaming::{
    open_planned, open_planned_with, open_sharded, plan_sharded, AccessPlan, ColumnSet,
    NoCensus, Predicate, PruneStats, SerialDecode, ShardTask, ShardedReader, StreamPlan,
    TraceShard, WindowFilter,
};

use crate::trace::Trace;
use anyhow::{bail, Result};
use std::path::Path;

/// Guess the format of `path` and read it.
pub fn read_auto(path: &Path) -> Result<Trace> {
    let ext = path.extension().and_then(|e| e.to_str()).unwrap_or("");
    if path.is_dir() {
        if path.join("defs.bin").exists() {
            return otf2::read(path, 0);
        }
        if path.join(archive::INDEX_FILE).exists() {
            return archive::read(path);
        }
        if path.join("meta.db").exists() {
            return hpctoolkit::read(path);
        }
        // Projections: any .sts file in the directory
        for entry in std::fs::read_dir(path)? {
            let p = entry?.path();
            if p.extension().and_then(|e| e.to_str()) == Some("sts") {
                return projections::read(path, 0);
            }
        }
        bail!("unrecognized trace directory: {}", path.display());
    }
    match ext {
        "csv" => csv::read(path),
        "json" => chrome::read(path),
        _ => bail!("unrecognized trace file: {}", path.display()),
    }
}

/// Run `f(i)` for `i in 0..n` on up to `threads` worker threads, preserving
/// result order. `threads == 0` means "number of available cores". This is
/// the parallel-read substrate shared by the OTF2 and Projections readers —
/// now backed by the shared worker pool in [`crate::exec::pool`], which
/// also cancels remaining tasks after the first error.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    crate::exec::pool::run_indexed(n, threads, f)
}

/// Resolve a `threads` parameter: 0 = available parallelism.
/// (Alias of [`crate::exec::effective_threads`], kept for callers.)
pub fn effective_threads(threads: usize) -> usize {
    crate::exec::effective_threads(threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| Ok(i * i)).unwrap();
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_serial_path() {
        let out = parallel_map(5, 1, |i| Ok(i)).unwrap();
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_map_propagates_errors() {
        let r = parallel_map(10, 4, |i| {
            if i == 7 {
                bail!("boom")
            } else {
                Ok(i)
            }
        });
        assert!(r.is_err());
    }
}
