//! Streaming shard-at-a-time ingest: [`ShardedReader`] yields
//! process-aligned [`TraceShard`]s incrementally, so the analysis driver
//! in [`crate::exec::stream`] never materializes the whole trace — peak
//! memory is bounded by O(workers × shard + results) instead of O(trace).
//!
//! | format      | strategy                                               |
//! |-------------|--------------------------------------------------------|
//! | otf2-dir    | one rank file decoded per shard (the flagship path)    |
//! | archive-dir | indexed compressed block per shard, zero pre-scan      |
//! | csv         | pre-scanned block byte ranges read from disk           |
//! | chrome json | pre-scanned block byte ranges read from disk (the raw  |
//! |             | text is never resident whole: the pre-scan itself runs |
//! |             | over a sliding `DiskCursor` window)                    |
//! | hpctoolkit  | split-after-load fallback ([`SplitReader`])            |
//! | projections | split-after-load fallback ([`SplitReader`])            |
//!
//! # The shard-task protocol (pipelined decode)
//!
//! Every reader splits a shard read into two halves:
//!
//! * [`ShardedReader::next_task`] — **I/O cursor advancement only** on
//!   the driver thread (read one rank file's compressed bytes, read one
//!   pre-scanned block's byte range), returning a [`ShardTask`];
//! * [`ShardTask::decode`] — the CPU half (zlib + varint parse, line /
//!   JSON parse), safe to run on **any** worker thread.
//!
//! The pipelined driver in [`crate::exec::stream`] maps decode tasks
//! over the worker pool so decoding overlaps analysis folds; shard
//! sequence numbers keep every fold in row order, so results stay
//! bit-identical to serial decode ([`SerialDecode`] pins the old
//! behavior for benchmarks and parity tests).
//!
//! # The span pre-pass (two-pass ingest)
//!
//! [`ShardedReader::scan_span`] reports the stream-wide (min, max)
//! timestamp **before any shard decodes**: otf2 reads the per-rank
//! extrema section of `defs.bin`, csv/chrome lift it from the same
//! byte-cursor pre-scan that finds block boundaries, and the fallbacks
//! read it off the already-loaded trace. Knowing the span up front lets
//! `time_profile` / `comm_over_time` fold shards directly into final
//! bins — O(bins) partial state instead of O(segments) / O(sends).
//!
//! The csv / chrome readers require process blocks to appear contiguous
//! and ascending (what every writer in this crate emits, and what
//! per-rank trace formats produce naturally); the pre-scan verifies this
//! and falls back to eager-load + [`SplitReader`] otherwise, so
//! `open_sharded` accepts everything `read_auto` accepts. The pre-scan
//! is split from reader construction ([`plan_sharded`] → [`StreamPlan`]
//! → [`open_planned`]) so sessions re-opening the same source per
//! analysis verify it once; fallbacks are surfaced to callers via
//! `StreamStats::fallback` rather than silently holding the whole trace.
//!
//! Determinism: concatenating shard rows in yield order reproduces the
//! canonical (Process, Thread, Timestamp) row order of the eager reader
//! exactly — the property every order-stable merge in
//! [`crate::exec::stream`] relies on to stay bit-identical with eager
//! `read_auto` + sequential analysis.

use super::census::{CensusAccum, TraceCensus};
use super::{chrome, csv, otf2};
use crate::df::Interner;
use crate::trace::{Trace, TraceBuilder, TraceMeta, RECV_EVENT, SEND_EVENT};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One process-aligned slice of a trace, in canonical row order.
pub struct TraceShard {
    /// Position in the stream (0-based); shard order is row order.
    pub index: usize,
    pub trace: Trace,
}

/// The raw payload of one shard plus the closure that decodes it — the
/// unit of pipelined ingest. Produced by pure I/O on the driver thread;
/// decoded on any worker (all shared reader state travels behind `Arc`s).
pub struct ShardTask {
    /// Position in the stream (0-based); task order is row order.
    pub index: usize,
    /// Payload bytes carried by the task until decoded (compressed rank
    /// bytes, block byte ranges — or the decoded trace's heap size for
    /// inline-decoded fallbacks) — what the adaptive read-ahead gate
    /// budgets.
    bytes: usize,
    decode: Box<dyn FnOnce() -> Result<Trace> + Send>,
}

impl ShardTask {
    /// Assemble a task from its parts (for sibling reader modules —
    /// `bytes` is the raw payload size the read-ahead gate budgets).
    pub(crate) fn new(
        index: usize,
        bytes: usize,
        decode: Box<dyn FnOnce() -> Result<Trace> + Send>,
    ) -> Self {
        ShardTask { index, bytes, decode }
    }

    /// Run the CPU half of the shard read (consumes the payload).
    pub fn decode(self) -> Result<Trace> {
        (self.decode)()
    }

    /// Decode in place into a [`TraceShard`] (the serial-decode path).
    pub fn into_shard(self) -> Result<TraceShard> {
        let index = self.index;
        Ok(TraceShard { index, trace: self.decode()? })
    }

    /// Raw payload bytes this task holds until decoded.
    pub fn payload_bytes(&self) -> usize {
        self.bytes
    }
}

/// Incremental, process-aligned trace reader.
pub trait ShardedReader {
    /// Yield the next shard in canonical row order, or None at end.
    fn next_shard(&mut self) -> Result<Option<TraceShard>>;

    /// Advance only the I/O cursor and return the next shard as a raw
    /// decode task, or None at end. The default decodes inline via
    /// [`ShardedReader::next_shard`] — correct for readers without a
    /// cheap raw payload (split-after-load fallbacks), and the behavior
    /// [`SerialDecode`] pins deliberately.
    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        Ok(self.next_shard()?.map(|sh| {
            let trace = sh.trace;
            // the payload here is the already-decoded trace: report its
            // heap size so the adaptive read-ahead gate sees it (a 0
            // would let 4× workers of decoded shards queue unbudgeted)
            let bytes = trace.events.heap_bytes();
            ShardTask { index: sh.index, bytes, decode: Box::new(move || Ok(trace)) }
        }))
    }

    /// Cheap span pre-pass: the stream-wide (min, max) timestamp of every
    /// row the reader will yield, known **before** any shard decodes
    /// (otf2 defs extrema, csv/chrome pre-scan, fallback's loaded
    /// trace). None when the source cannot provide it cheaply — drivers
    /// then buffer span-dependent partials until end of stream, exactly
    /// as before the two-pass protocol.
    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        Ok(None)
    }

    /// The pre-scan [`TraceCensus`] (per-block metadata, function census
    /// with exclusive-time rank hints, channel endpoint census, message
    /// extrema), known **before** any shard decodes: csv/chrome lift it
    /// from the same byte-cursor pre-scan that finds block boundaries;
    /// otf2 reads the `defs.bin` census trailing section. None when the
    /// source cannot provide it (old archives, forfeited pre-scans,
    /// split-after-load fallbacks) — consumers then run their census-less
    /// legacy paths, exactly as before the census existed.
    fn census(&self) -> Option<&TraceCensus> {
        None
    }

    /// True when the source carried a census that failed validation
    /// (corrupt / truncated otf2 trailing section): the census-less
    /// legacy paths run, and drivers surface the degradation via
    /// `StreamStats::fallback` instead of erroring.
    fn census_corrupt(&self) -> bool {
        false
    }

    /// What the reader's access plan let it skip: blocks pruned by span /
    /// predicate, their compressed bytes never read, and per-column
    /// chunks never inflated. Zero for readers without storage-layer
    /// pruning (everything but the archive) — the driver stamps this
    /// into `StreamStats` after the fold so the win is observable.
    fn prune_stats(&self) -> PruneStats {
        PruneStats::default()
    }

    /// Number of shards this reader will yield, when known up front.
    fn shard_count_hint(&self) -> Option<usize>;

    /// True when shards decode incrementally from the source (bounded
    /// memory); false for split-after-load fallbacks, which hold the
    /// whole trace while yielding.
    fn is_streaming(&self) -> bool;

    /// For split-after-load fallbacks: recover the already-loaded trace
    /// instead of throwing the parse away (consumes the reader).
    /// Streaming readers return None. Callers that would otherwise
    /// re-open the source repeatedly (e.g. a session keeping a
    /// non-streamable entry) use this to avoid paying a full re-read per
    /// analysis.
    fn into_eager_trace(self: Box<Self>) -> Option<Trace> {
        None
    }
}

/// Adapter pinning shard decode to the driver thread: `next_task`
/// decodes inline (the trait default), so the pipelined driver degrades
/// to the pre-pipeline serial-decode behavior with everything else
/// unchanged. Benchmarks use it as the baseline the decode pipeline is
/// gated against; parity tests use it to prove pipelining changes no
/// bits.
pub struct SerialDecode<'a>(&'a mut dyn ShardedReader);

impl<'a> SerialDecode<'a> {
    pub fn new(inner: &'a mut dyn ShardedReader) -> Self {
        SerialDecode(inner)
    }
}

impl ShardedReader for SerialDecode<'_> {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        self.0.next_shard()
    }

    // next_task: trait default — decode inline on the calling thread.

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        self.0.scan_span()
    }

    fn census(&self) -> Option<&TraceCensus> {
        self.0.census()
    }

    fn census_corrupt(&self) -> bool {
        self.0.census_corrupt()
    }

    fn prune_stats(&self) -> PruneStats {
        self.0.prune_stats()
    }

    fn shard_count_hint(&self) -> Option<usize> {
        self.0.shard_count_hint()
    }

    fn is_streaming(&self) -> bool {
        self.0.is_streaming()
    }
}

/// Adapter hiding the pre-scan census: analyses run their census-less
/// legacy paths (end-of-stream channel buffering, all-slot time-profile
/// rows, histogram re-bin) with everything else — span pre-pass, shard
/// tasks — unchanged. Benchmarks use it as the baseline the census paths
/// are gated against; parity tests use it to prove the census changes no
/// bits.
pub struct NoCensus<'a>(&'a mut dyn ShardedReader);

impl<'a> NoCensus<'a> {
    pub fn new(inner: &'a mut dyn ShardedReader) -> Self {
        NoCensus(inner)
    }
}

impl ShardedReader for NoCensus<'_> {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        self.0.next_shard()
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        self.0.next_task()
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        self.0.scan_span()
    }

    // census / census_corrupt: trait defaults — the census stays hidden.

    fn prune_stats(&self) -> PruneStats {
        self.0.prune_stats()
    }

    fn shard_count_hint(&self) -> Option<usize> {
        self.0.shard_count_hint()
    }

    fn is_streaming(&self) -> bool {
        self.0.is_streaming()
    }
}

// -- the access descriptor: what an analysis will actually read -------------

/// The set of event columns an analysis reads, as a bitmask over the
/// seven non-process columns (the process id is structural — blocks are
/// process-aligned — and is always materialized). Storage layers that
/// frame columns independently (archive v2) inflate only the named
/// columns; everything else ignores the mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColumnSet(u8);

impl ColumnSet {
    pub const TS: u8 = 1 << 0;
    pub const TYPE: u8 = 1 << 1;
    pub const NAME: u8 = 1 << 2;
    pub const THREAD: u8 = 1 << 3;
    pub const PARTNER: u8 = 1 << 4;
    pub const MSG_SIZE: u8 = 1 << 5;
    pub const TAG: u8 = 1 << 6;
    const ALL: u8 = 0x7f;

    /// Every column (the no-projection plan).
    pub fn all() -> ColumnSet {
        ColumnSet(Self::ALL)
    }

    /// A mask of the given bits; the timestamp column is always read
    /// (canonical row order depends on it).
    pub fn of(bits: u8) -> ColumnSet {
        ColumnSet((bits | Self::TS) & Self::ALL)
    }

    pub fn has(&self, bit: u8) -> bool {
        self.0 & bit != 0
    }

    pub fn with(self, bits: u8) -> ColumnSet {
        ColumnSet::of(self.0 | bits)
    }

    pub fn is_all(&self) -> bool {
        self.0 == Self::ALL
    }

    /// How many of the seven maskable columns are skipped.
    pub fn num_skipped(&self) -> usize {
        7 - self.0.count_ones() as usize
    }
}

/// A block-level relevance predicate a storage layer may prove false
/// from its per-block sub-census — the conservative contract: a block is
/// skipped **only** when the census proves no row of it can contribute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Predicate {
    /// No predicate: every block in the window is relevant.
    None,
    /// The analysis only reads point-to-point traffic with a real
    /// partner (`message_histogram`): a block whose channel sub-census
    /// records no send/recv endpoints cannot contribute.
    ChannelTraffic,
}

/// What a routed analysis will read: the column projection, an optional
/// inclusive `[start, end]` time window (complete-call semantics — see
/// [`crate::exec::ops::window_rows`]), and an optional block predicate.
/// Built per op by [`AccessPlan::for_op`]; [`AccessPlan::full`] is the
/// read-everything plan every pre-planner source uses.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AccessPlan {
    pub columns: ColumnSet,
    pub window: Option<(Option<i64>, Option<i64>)>,
    pub predicate: Predicate,
}

impl AccessPlan {
    /// Read everything: all columns, no window, no predicate.
    pub fn full() -> AccessPlan {
        AccessPlan { columns: ColumnSet::all(), window: None, predicate: Predicate::None }
    }

    /// The access descriptor of a routed op: exactly the columns its
    /// sequential/sharded/streamed engines read (so a projected decode
    /// is bit-identical), plus the block predicate its semantics allow.
    /// Unknown op names conservatively read everything.
    pub fn for_op(op: &str) -> AccessPlan {
        use ColumnSet as C;
        let (columns, predicate) = match op {
            // segment folds keyed by name: stack walk over ts/type/name
            "flat_profile" | "load_imbalance" | "idle_time" => {
                (C::of(C::TYPE | C::NAME), Predicate::None)
            }
            // exclusive segments are per (proc, thread)
            "time_profile" | "cct" | "comm_comp_breakdown" | "pattern_detection" => {
                (C::of(C::TYPE | C::NAME | C::THREAD), Predicate::None)
            }
            // send/recv rows: name + partner + size (type-independent)
            "comm_matrix" | "comm_by_process" => {
                (C::of(C::NAME | C::PARTNER | C::MSG_SIZE), Predicate::None)
            }
            // only real point-to-point rows (partner != null) count, so
            // endpoint-free blocks are provably irrelevant
            "message_histogram" => {
                (C::of(C::NAME | C::PARTNER | C::MSG_SIZE), Predicate::ChannelTraffic)
            }
            // sends are binned by timestamp; partner is never read
            "comm_over_time" => (C::of(C::NAME | C::MSG_SIZE), Predicate::None),
            // channel matching + per-process run segments: all but size
            "critical_path" | "lateness" => {
                (C::of(C::TYPE | C::NAME | C::THREAD | C::PARTNER | C::TAG), Predicate::None)
            }
            _ => (C::all(), Predicate::None),
        };
        AccessPlan { columns, window: None, predicate }
    }

    /// Restrict the plan to a time window. The complete-call filter
    /// itself walks ts/type/proc/thread, so windowing forces the type
    /// and thread columns into the projection.
    pub fn windowed(mut self, start: Option<i64>, end: Option<i64>) -> AccessPlan {
        if start.is_some() || end.is_some() {
            self.window = Some((start, end));
            self.columns = self.columns.with(ColumnSet::TYPE | ColumnSet::THREAD);
        }
        self
    }
}

/// What an access-planned reader skipped (all zero when nothing was).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Blocks never scheduled: span missed the window, or the block's
    /// sub-census proved the predicate false.
    pub blocks_pruned: usize,
    /// Compressed bytes of pruned blocks and of projected-out column
    /// chunks — bytes never read or never inflated.
    pub bytes_skipped: u64,
    /// Per-column chunks of surviving blocks that were never inflated.
    pub columns_skipped: u64,
}

/// Adapter applying a time window to any sharded reader: each shard's
/// decode is wrapped with the complete-call filter
/// ([`crate::exec::ops::window_rows`]), and the census / span pre-pass
/// are hidden (they describe the unfiltered stream) so every consumer
/// runs its census-less legacy path — the same bits as filtering the
/// eager trace. The archive reader windows natively (block pruning +
/// in-decode filtering); this adapter serves every other source.
pub struct WindowFilter {
    inner: Box<dyn ShardedReader>,
    lo: i64,
    hi: i64,
}

impl WindowFilter {
    pub fn new(inner: Box<dyn ShardedReader>, start: Option<i64>, end: Option<i64>) -> Self {
        WindowFilter {
            inner,
            lo: start.unwrap_or(i64::MIN),
            hi: end.unwrap_or(i64::MAX),
        }
    }
}

impl ShardedReader for WindowFilter {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        match self.next_task()? {
            Some(task) => Ok(Some(task.into_shard()?)),
            None => Ok(None),
        }
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        let Some(task) = self.inner.next_task()? else {
            return Ok(None);
        };
        let (lo, hi) = (self.lo, self.hi);
        let ShardTask { index, bytes, decode } = task;
        Ok(Some(ShardTask {
            index,
            bytes,
            decode: Box::new(move || crate::exec::ops::window_rows(&decode()?, lo, hi)),
        }))
    }

    // scan_span / census: trait defaults (None) — both describe the
    // unfiltered stream, so windowed consumers must not see them.

    fn census_corrupt(&self) -> bool {
        self.inner.census_corrupt()
    }

    fn prune_stats(&self) -> PruneStats {
        self.inner.prune_stats()
    }

    fn shard_count_hint(&self) -> Option<usize> {
        self.inner.shard_count_hint()
    }

    fn is_streaming(&self) -> bool {
        self.inner.is_streaming()
    }
}

/// The cached result of the streamability pre-scan. Sessions keep one
/// per stream-backed entry so repeated routed analyses skip the
/// re-verification — for csv/chrome the pre-scan walks every line /
/// event object once, recording block byte offsets (so re-opens are
/// pure seeks) and the stream-wide time span (so two-pass analyses bin
/// without buffering).
#[derive(Debug, Clone, PartialEq)]
pub enum StreamPlan {
    /// OTF2-sim directory: one rank file per shard, no pre-scan needed
    /// (defs.bin carries the rank list and span extrema).
    Otf2,
    /// Pipit archive directory: the index carries block offsets, spans
    /// and the full census — reopening is pure seeks, zero pre-scan.
    Archive,
    /// Canonically-ordered csv: block byte ranges stream from disk.
    Csv(CsvPlan),
    /// Canonically-ordered chrome json: block byte ranges stream from
    /// disk, plus the application name lifted from metadata records.
    Chrome(ChromePlan),
    /// Not streamable (hpctoolkit / projections / interleaved files):
    /// eager load + [`SplitReader`].
    Fallback,
}

/// Pre-scan verdict for a streamable csv file.
#[derive(Debug, Clone, PartialEq)]
pub struct CsvPlan {
    /// (byte offset, 1-based file line number) of each process block's
    /// first line; a block runs to the next block's offset (or EOF).
    blocks: Vec<(u64, usize)>,
    /// Stream-wide (min, max) ns timestamp; None when some row's
    /// timestamp did not parse (the full decode owns that error).
    span: Option<(i64, i64)>,
    /// The pre-scan census; None when a row the decode will reject was
    /// seen (census-less fallbacks run, the decode owns the error).
    census: Option<TraceCensus>,
}

impl CsvPlan {
    /// Number of process blocks (= shards).
    pub fn runs(&self) -> usize {
        self.blocks.len()
    }
}

/// Pre-scan verdict for a streamable chrome trace file.
#[derive(Debug, Clone, PartialEq)]
pub struct ChromePlan {
    /// Application name lifted from `process_name` metadata records.
    app: String,
    /// (byte offset, event index) of each pid block's first row event;
    /// a block runs to the next block's offset (or `end`).
    blocks: Vec<(u64, usize)>,
    /// Byte offset just past the last event in the events array.
    end: u64,
    /// Stream-wide (min, max) ns timestamp over every row the events
    /// produce (X events contribute `ts` and `ts + dur`).
    span: Option<(i64, i64)>,
    /// The pre-scan census; None when an event the decode will reject
    /// was seen (census-less fallbacks run, the decode owns the error).
    census: Option<TraceCensus>,
}

impl ChromePlan {
    /// Number of pid blocks (= shards).
    pub fn runs(&self) -> usize {
        self.blocks.len()
    }

    pub fn app(&self) -> &str {
        &self.app
    }
}

impl StreamPlan {
    /// Will [`open_planned`] yield a truly streaming reader?
    pub fn is_streaming(&self) -> bool {
        !matches!(self, StreamPlan::Fallback)
    }
}

/// Run only the streamability pre-scan, without opening a reader —
/// mirrors [`super::read_auto`]'s format detection.
pub fn plan_sharded(path: &Path) -> Result<StreamPlan> {
    if path.is_dir() {
        if path.join("defs.bin").exists() {
            return Ok(StreamPlan::Otf2);
        }
        if path.join(super::archive::INDEX_FILE).exists() {
            return Ok(StreamPlan::Archive);
        }
        if path.join("meta.db").exists() {
            return Ok(StreamPlan::Fallback);
        }
        for entry in std::fs::read_dir(path)? {
            let p = entry?.path();
            if p.extension().and_then(|e| e.to_str()) == Some("sts") {
                return Ok(StreamPlan::Fallback);
            }
        }
        bail!("unrecognized trace directory: {}", path.display());
    }
    match path.extension().and_then(|e| e.to_str()).unwrap_or("") {
        "csv" => Ok(match csv_prescan(path)? {
            Some(plan) => StreamPlan::Csv(plan),
            None => StreamPlan::Fallback,
        }),
        "json" => Ok(match chrome_prescan(path)? {
            Some(plan) => StreamPlan::Chrome(plan),
            None => StreamPlan::Fallback,
        }),
        _ => bail!("unrecognized trace file: {}", path.display()),
    }
}

/// Open a reader for a previously computed [`StreamPlan`], skipping the
/// pre-scan (sessions cache the plan per entry and re-open cheaply per
/// analysis — block offsets make csv/chrome re-opens pure seeks).
pub fn open_planned(path: &Path, plan: &StreamPlan) -> Result<Box<dyn ShardedReader>> {
    match plan {
        StreamPlan::Otf2 => Ok(Box::new(Otf2ShardedReader::open(path)?)),
        StreamPlan::Archive => Ok(Box::new(super::archive::ArchiveBlocks::open(path)?)),
        StreamPlan::Csv(p) => Ok(Box::new(CsvBlocks::open(path, p.clone())?)),
        StreamPlan::Chrome(p) => Ok(Box::new(ChromeBlocks::open(path, p.clone())?)),
        StreamPlan::Fallback => {
            Ok(Box::new(SplitReader::new(super::read_auto(path)?)?))
        }
    }
}

/// Open `path` as a sharded reader with format auto-detection, mirroring
/// [`super::read_auto`]: plan + open in one call.
pub fn open_sharded(path: &Path) -> Result<Box<dyn ShardedReader>> {
    open_planned(path, &plan_sharded(path)?)
}

/// Open a reader for a plan under an access descriptor. Archives plan
/// natively (block pruning, column projection, windowed decode —
/// [`super::archive::ArchiveBlocks::open_with`]); every other source
/// reads fully, with a [`WindowFilter`] applied when the plan carries a
/// window. Results are bit-identical to [`open_planned`] + eager
/// filtering on every engine.
pub fn open_planned_with(
    path: &Path,
    plan: &StreamPlan,
    access: &AccessPlan,
) -> Result<Box<dyn ShardedReader>> {
    if matches!(plan, StreamPlan::Archive) {
        return Ok(Box::new(super::archive::ArchiveBlocks::open_with(path, access)?));
    }
    let inner = open_planned(path, plan)?;
    Ok(match access.window {
        Some((lo, hi)) => Box::new(WindowFilter::new(inner, lo, hi)),
        None => inner,
    })
}

// -- split-after-load fallback ---------------------------------------------

/// Fallback reader: an eagerly-loaded trace yielded one process at a
/// time. Memory is O(trace) during iteration; row order and per-shard
/// alignment are identical to the truly-streaming readers, so every
/// downstream merge behaves the same.
pub struct SplitReader {
    trace: Trace,
    ranges: Vec<(usize, usize)>,
    next: usize,
}

impl SplitReader {
    pub fn new(trace: Trace) -> Result<Self> {
        let shards = crate::exec::process_shards(&trace, usize::MAX)?;
        Ok(SplitReader { trace, ranges: shards.ranges, next: 0 })
    }
}

impl ShardedReader for SplitReader {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        if self.next >= self.ranges.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let trace = crate::exec::subtrace(&self.trace, self.ranges[index])?;
        Ok(Some(TraceShard { index, trace }))
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        // the trace is resident anyway; its range is free
        Ok(Some(self.trace.time_range()?))
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.ranges.len())
    }

    fn is_streaming(&self) -> bool {
        false
    }

    fn into_eager_trace(self: Box<Self>) -> Option<Trace> {
        Some(self.trace)
    }
}

// -- otf2: one rank file per shard -----------------------------------------

/// OTF2-sim streaming reader: global defs are read once; each
/// `rank_<r>.bin` stream decodes on demand into one shard. This is true
/// bounded-memory ingest — only one rank's events exist at a time, and
/// the shared `Arc` dictionaries keep name codes identical across shards.
/// `next_task` reads only the compressed rank bytes (pure I/O); the zlib
/// + varint decode runs wherever the task is executed.
pub struct Otf2ShardedReader {
    dir: PathBuf,
    defs: Arc<otf2::Defs>,
    etype_dict: Arc<Interner>,
    etypes: otf2::EtypeCodes,
    next: usize,
}

impl Otf2ShardedReader {
    pub fn open(dir: &Path) -> Result<Self> {
        let defs = Arc::new(otf2::read_defs(dir)?);
        let (etype_dict, etypes) = otf2::etype_codes();
        Ok(Otf2ShardedReader { dir: dir.to_path_buf(), defs, etype_dict, etypes, next: 0 })
    }
}

impl ShardedReader for Otf2ShardedReader {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        match self.next_task()? {
            Some(task) => Ok(Some(task.into_shard()?)),
            None => Ok(None),
        }
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        if self.next >= self.defs.ranks.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let rank = self.defs.ranks[index];
        let raw = otf2::rank_bytes(&self.dir, rank)?;
        let defs = Arc::clone(&self.defs);
        let etype_dict = Arc::clone(&self.etype_dict);
        let etypes = self.etypes;
        let meta = TraceMeta {
            format: "otf2".into(),
            source: self.dir.display().to_string(),
            app: self.defs.app.clone(),
        };
        let bytes = raw.len();
        Ok(Some(ShardTask {
            index,
            bytes,
            decode: Box::new(move || {
                let sh = otf2::decode_rank(&raw, rank, &defs, &etypes)?;
                let table = otf2::shard_table(sh, &defs.names, &etype_dict)?;
                Ok(Trace::new(table, meta))
            }),
        }))
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        // None for archives written before the extrema section existed
        Ok(self.defs.span())
    }

    fn census(&self) -> Option<&TraceCensus> {
        self.defs.census.as_ref()
    }

    fn census_corrupt(&self) -> bool {
        self.defs.census_corrupt
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.defs.ranks.len())
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- csv: pre-scanned block byte ranges -------------------------------------

/// Streamability pre-scan: one pass over the file parsing every line's
/// fields leniently — the Process field (grouping), the Timestamp field
/// (span + per-block extrema), and the event interpretation (function /
/// channel / message census). `Ok(None)` requests the eager fallback
/// (which also owns producing proper errors for malformed files); a line
/// the decode will reject forfeits only the census, not streamability.
fn csv_prescan(path: &Path) -> Result<Option<CsvPlan>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut r = std::io::BufReader::new(f);
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    let Ok(h) = csv::parse_header(&line) else {
        return Ok(None);
    };
    let mut offset = n as u64;
    let mut line_no = 1usize;
    let mut blocks: Vec<(u64, usize)> = Vec::new();
    let mut last: Option<i64> = None;
    let mut span: Option<(i64, i64)> = None;
    let mut span_ok = true;
    let mut accum = CensusAccum::new();
    loop {
        line.clear();
        let start = offset;
        let n = r.read_line(&mut line)?;
        if n == 0 {
            break;
        }
        offset += n as u64;
        line_no += 1;
        if line.trim().is_empty() {
            continue;
        }
        let fields = csv::split_fields(&line);
        let Some(row) = csv::prescan_row(&h, &fields) else {
            return Ok(None);
        };
        let p = row.proc;
        match last {
            Some(q) if p == q => {}
            Some(q) if p > q => {
                accum.end_block(q);
                blocks.push((start, line_no));
                last = Some(p);
            }
            Some(_) => return Ok(None), // process reappeared: not grouped
            None => {
                blocks.push((start, line_no));
                last = Some(p);
            }
        }
        match row.ts {
            Some(ts) => {
                if span_ok {
                    span = Some(match span {
                        Some((lo, hi)) => (lo.min(ts), hi.max(ts)),
                        None => (ts, ts),
                    });
                }
                accum.row(ts);
            }
            // unparsable timestamp: the decode will error with the
            // proper message; span and census are forfeited
            None => {
                span_ok = false;
                accum.forfeit();
            }
        }
        match (row.ts, row.event) {
            (Some(ts), Some(ev)) => match ev {
                csv::PrescanEvent::Enter(name) => accum.enter(row.thread, ts, name),
                csv::PrescanEvent::Leave(name) => accum.leave(row.thread, ts, name),
                csv::PrescanEvent::Send { partner, size, tag } => {
                    accum.send(p, partner, tag, size)
                }
                csv::PrescanEvent::Recv { partner, size, tag } => {
                    accum.recv(p, partner, tag, size)
                }
                csv::PrescanEvent::Instant => {}
            },
            // uninterpretable event: the decode will reject this line
            (_, None) => accum.forfeit(),
            (None, _) => {}
        }
    }
    if let Some(q) = last {
        accum.end_block(q);
    }
    Ok(Some(CsvPlan {
        blocks,
        span: if span_ok { span } else { None },
        census: accum.finish(),
    }))
}

/// Parse one pre-scanned csv block (complete lines) into a shard trace.
/// `first_line` is the 1-based file line number of the block's first
/// line, so error messages match the eager reader's exactly.
fn decode_csv_block(
    bytes: &[u8],
    h: &csv::CsvHeader,
    meta: TraceMeta,
    first_line: usize,
) -> Result<Trace> {
    let text = std::str::from_utf8(bytes).context("csv block is not valid utf-8")?;
    let mut b = TraceBuilder::new();
    b.set_meta(meta);
    for (k, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = csv::parse_row(h, line, first_line + k)?;
        csv::apply_row(&mut b, &row);
    }
    Ok(b.finish())
}

/// Streaming csv reader over pre-scanned block byte ranges: the driver
/// side is a seek + read per shard; line parsing happens in the decode
/// task.
struct CsvBlocks {
    file: std::fs::File,
    len: u64,
    header: Arc<csv::CsvHeader>,
    meta: TraceMeta,
    plan: CsvPlan,
    next: usize,
}

impl CsvBlocks {
    fn open(path: &Path, plan: CsvPlan) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let len = file.metadata()?.len();
        let mut header_line = String::new();
        std::io::BufReader::new(&file).read_line(&mut header_line)?;
        if header_line.is_empty() {
            bail!("empty csv");
        }
        let header = Arc::new(csv::parse_header(&header_line)?);
        Ok(CsvBlocks { file, len, header, meta: csv::csv_meta(path), plan, next: 0 })
    }
}

impl ShardedReader for CsvBlocks {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        match self.next_task()? {
            Some(task) => Ok(Some(task.into_shard()?)),
            None => Ok(None),
        }
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        if self.next >= self.plan.blocks.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let (start, first_line) = self.plan.blocks[index];
        let end = self.plan.blocks.get(index + 1).map(|b| b.0).unwrap_or(self.len);
        self.file.seek(SeekFrom::Start(start))?;
        let mut bytes = vec![0u8; (end - start) as usize];
        self.file.read_exact(&mut bytes)?;
        let header = Arc::clone(&self.header);
        let meta = self.meta.clone();
        let len = bytes.len();
        Ok(Some(ShardTask {
            index,
            bytes: len,
            decode: Box::new(move || decode_csv_block(&bytes, &header, meta, first_line)),
        }))
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        Ok(self.plan.span)
    }

    fn census(&self) -> Option<&TraceCensus> {
        self.plan.census.as_ref()
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.plan.blocks.len())
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- chrome: disk-cursor pre-scan + block byte ranges ------------------------

/// Streamability pre-scan over a sliding disk window: walk every event
/// object (never holding the whole file), collect the application name
/// from metadata records, the stream-wide span, the byte offset + event
/// index of each pid block's first row event, and the census. None
/// requests the eager fallback (including for malformed files, whose
/// errors the eager reader reports properly).
///
/// Census memory note: the function census buffers each pid block's
/// Enter/Leave tuples (16 B each) so they can be canonically re-sorted —
/// O(largest block) compact tuples, far below the decoded shard the
/// ingest holds anyway; the sliding window itself stays O(chunk).
fn chrome_prescan(path: &Path) -> Result<Option<ChromePlan>> {
    let mut cur = DiskCursor::open(path)?;
    let Ok(start) = find_events_array_cursor(&mut cur) else {
        return Ok(None);
    };
    let mut pos = start;
    let mut blocks: Vec<(u64, usize)> = Vec::new();
    let mut end = start;
    let mut last: Option<i64> = None;
    let mut app = String::new();
    let mut event_idx = 0usize;
    let mut span: Option<(i64, i64)> = None;
    let mut span_ok = true;
    let mut accum = CensusAccum::new();
    loop {
        // everything before the next event is consumed: slide the window
        cur.compact(pos);
        let bounds = match cur.next_event_bounds(&mut pos) {
            Ok(b) => b,
            Err(_) => return Ok(None),
        };
        let Some((s, e)) = bounds else { break };
        let idx = event_idx;
        event_idx += 1;
        end = e;
        let Ok(text) = std::str::from_utf8(cur.slice(s, e)) else {
            return Ok(None);
        };
        let Ok(ev) = Json::parse(text) else {
            return Ok(None);
        };
        if !chrome::is_row_event(&ev) {
            if ev.get_str("ph") == Some("M") && ev.get_str("name") == Some("process_name") {
                if let Some(n) = ev.get("args").and_then(|a| a.get_str("name")) {
                    app = n.to_string();
                }
            }
            continue;
        }
        let pid = chrome::event_pid(&ev);
        match last {
            Some(q) if pid == q => {}
            Some(q) if pid > q => {
                accum.end_block(q);
                blocks.push((s, idx));
                last = Some(pid);
            }
            Some(_) => return Ok(None),
            None => {
                blocks.push((s, idx));
                last = Some(pid);
            }
        }
        let (ts, te) = chrome::row_event_times(&ev);
        let ph = ev.get_str("ph").unwrap_or("X");
        if span_ok {
            match (te, ph == "X") {
                // X without dur: the decode will error; span forfeited
                (None, true) => span_ok = false,
                (te, _) => {
                    let hi = te.unwrap_or(ts).max(ts);
                    let lo = te.unwrap_or(ts).min(ts);
                    span = Some(match span {
                        Some((a, b)) => (a.min(lo), b.max(hi)),
                        None => (lo, hi),
                    });
                }
            }
        }
        // census: mirror `chrome::apply_event`'s row production exactly
        let name = ev.get_str("name").unwrap_or("<unnamed>");
        let tid = chrome::event_tid(&ev);
        match ph {
            "B" => {
                accum.row(ts);
                accum.enter(tid, ts, name);
            }
            "E" => {
                accum.row(ts);
                accum.leave(tid, ts, name);
            }
            "X" => match te {
                Some(te) => {
                    accum.row(ts);
                    accum.row(te);
                    accum.enter(tid, ts, name);
                    accum.leave(tid, te, name);
                }
                // the decode will reject this event
                None => accum.forfeit(),
            },
            _ => {
                // instant phases (i / I / R)
                accum.row(ts);
                let (partner, size, tag) = chrome::event_msg_args(&ev);
                match name {
                    SEND_EVENT | "ncclSend" => accum.send(pid, partner, tag, size),
                    RECV_EVENT | "ncclRecv" => accum.recv(pid, partner, tag, size),
                    _ => {}
                }
            }
        }
    }
    if let Some(q) = last {
        accum.end_block(q);
    }
    Ok(Some(ChromePlan {
        app,
        blocks,
        end,
        span: if span_ok { span } else { None },
        census: accum.finish(),
    }))
}

/// Parse one pre-scanned chrome block (complete `{...}` events separated
/// by commas/whitespace) into a shard trace. `first_idx` is the index of
/// the block's first event within the whole events array, so error
/// messages match the eager reader's exactly. Metadata events inside the
/// range parse and contribute no rows (their app name was already lifted
/// by the pre-scan).
fn decode_chrome_block(bytes: &[u8], meta: TraceMeta, first_idx: usize) -> Result<Trace> {
    let mut b = TraceBuilder::new();
    b.set_meta(meta);
    let mut pos = 0usize;
    let mut idx = first_idx;
    loop {
        skip_ws(bytes, &mut pos);
        match bytes.get(pos) {
            None => break,
            Some(b',') => {
                pos += 1;
                continue;
            }
            Some(_) => {}
        }
        let start = pos;
        scan_value(bytes, &mut pos)?;
        let ev = Json::parse(std::str::from_utf8(&bytes[start..pos])?)?;
        chrome::apply_event(&mut b, &ev, idx)?;
        idx += 1;
    }
    Ok(b.finish())
}

/// Streaming chrome reader over pre-scanned block byte ranges: the
/// driver side is a seek + read per shard; JSON parsing happens in the
/// decode task. Unlike the first-generation scanner, the raw file text
/// is never resident whole — neither here nor in the pre-scan.
struct ChromeBlocks {
    file: std::fs::File,
    meta: TraceMeta,
    plan: ChromePlan,
    next: usize,
}

impl ChromeBlocks {
    fn open(path: &Path, plan: ChromePlan) -> Result<Self> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let meta = TraceMeta {
            format: "chrome".into(),
            source: path.display().to_string(),
            app: plan.app.clone(),
        };
        Ok(ChromeBlocks { file, meta, plan, next: 0 })
    }
}

impl ShardedReader for ChromeBlocks {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        match self.next_task()? {
            Some(task) => Ok(Some(task.into_shard()?)),
            None => Ok(None),
        }
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        if self.next >= self.plan.blocks.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let (start, first_idx) = self.plan.blocks[index];
        let end = self.plan.blocks.get(index + 1).map(|b| b.0).unwrap_or(self.plan.end);
        self.file.seek(SeekFrom::Start(start))?;
        let mut bytes = vec![0u8; (end - start) as usize];
        self.file.read_exact(&mut bytes)?;
        let meta = self.meta.clone();
        let len = bytes.len();
        Ok(Some(ShardTask {
            index,
            bytes: len,
            decode: Box::new(move || decode_chrome_block(&bytes, meta, first_idx)),
        }))
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        Ok(self.plan.span)
    }

    fn census(&self) -> Option<&TraceCensus> {
        self.plan.census.as_ref()
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.plan.blocks.len())
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- incremental JSON scanning ----------------------------------------------
//
// Just enough lexing to slice one `{...}` event out of the (possibly
// huge) events array; each slice then goes through the full
// `Json::parse`, so event *interpretation* is byte-for-byte the eager
// reader's. Every scanner is written against a possibly-incomplete
// buffer: `Scan::NeedMore` means the buffer ended before the item did
// and more file bytes must be read (only reported while the cursor has
// not reached EOF — at EOF the same condition is a hard error, matching
// the whole-buffer scanners of the first generation).

enum Scan<T> {
    Done(T),
    NeedMore,
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while let Some(c) = b.get(*pos) {
        if c.is_ascii_whitespace() {
            *pos += 1;
        } else {
            break;
        }
    }
}

fn scan_string2(b: &[u8], pos: &mut usize, eof: bool) -> Result<Scan<()>> {
    *pos += 1; // opening quote
    while let Some(&c) = b.get(*pos) {
        match c {
            b'\\' => *pos += 2,
            b'"' => {
                *pos += 1;
                return Ok(Scan::Done(()));
            }
            _ => *pos += 1,
        }
    }
    if eof {
        bail!("chrome trace: unterminated string")
    }
    Ok(Scan::NeedMore)
}

/// Advance past one JSON value of any kind (balanced braces / brackets,
/// string-aware).
fn scan_value2(b: &[u8], pos: &mut usize, eof: bool) -> Result<Scan<()>> {
    match b.get(*pos) {
        Some(b'"') => scan_string2(b, pos, eof),
        Some(b'{') | Some(b'[') => {
            let mut depth = 0usize;
            loop {
                match b.get(*pos) {
                    None => {
                        if eof {
                            bail!("chrome trace: unbalanced brackets")
                        }
                        return Ok(Scan::NeedMore);
                    }
                    Some(b'"') => {
                        match scan_string2(b, pos, eof)? {
                            Scan::Done(()) => continue,
                            Scan::NeedMore => return Ok(Scan::NeedMore),
                        }
                    }
                    Some(b'{') | Some(b'[') => depth += 1,
                    Some(b'}') | Some(b']') => {
                        depth -= 1;
                        if depth == 0 {
                            *pos += 1;
                            return Ok(Scan::Done(()));
                        }
                    }
                    Some(_) => {}
                }
                *pos += 1;
            }
        }
        Some(_) => {
            // bare literal: ends at a delimiter; at a buffer boundary we
            // cannot know whether it continues, so wait for more bytes
            while let Some(&c) = b.get(*pos) {
                if c == b',' || c == b']' || c == b'}' || c.is_ascii_whitespace() {
                    return Ok(Scan::Done(()));
                }
                *pos += 1;
            }
            if eof {
                Ok(Scan::Done(()))
            } else {
                Ok(Scan::NeedMore)
            }
        }
        None => {
            if eof {
                bail!("chrome trace: unexpected end of input")
            }
            Ok(Scan::NeedMore)
        }
    }
}

/// Whole-buffer wrapper (buffer known complete).
fn scan_value(b: &[u8], pos: &mut usize) -> Result<()> {
    match scan_value2(b, pos, true)? {
        Scan::Done(()) => Ok(()),
        Scan::NeedMore => bail!("chrome trace: unexpected end of input"),
    }
}

/// Position just past the `[` of the events array: the document root for
/// array-form files, the `traceEvents` value for object-form files.
/// (The pre-scan itself uses the cursor-native
/// [`find_events_array_cursor`], which skips huge pre-`traceEvents`
/// values in O(chunk) memory; this whole-buffer variant remains the
/// reference the scanner unit tests exercise.)
#[cfg(test)]
fn find_events_array2(b: &[u8], pos: &mut usize, eof: bool) -> Result<Scan<()>> {
    skip_ws(b, pos);
    match b.get(*pos) {
        Some(b'[') => {
            *pos += 1;
            Ok(Scan::Done(()))
        }
        Some(b'{') => {
            *pos += 1;
            loop {
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b'"') => {}
                    None if !eof => return Ok(Scan::NeedMore),
                    Some(b'}') | None => bail!("object form requires 'traceEvents' array"),
                    Some(b',') => {
                        *pos += 1;
                        continue;
                    }
                    Some(_) => bail!("chrome trace: expected object key"),
                }
                let kstart = *pos;
                match scan_string2(b, pos, eof)? {
                    Scan::Done(()) => {}
                    Scan::NeedMore => return Ok(Scan::NeedMore),
                }
                let is_events = &b[kstart + 1..*pos - 1] == b"traceEvents";
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b':') => *pos += 1,
                    None if !eof => return Ok(Scan::NeedMore),
                    _ => bail!("chrome trace: expected ':' after key"),
                }
                skip_ws(b, pos);
                if is_events {
                    return match b.get(*pos) {
                        Some(b'[') => {
                            *pos += 1;
                            Ok(Scan::Done(()))
                        }
                        None if !eof => Ok(Scan::NeedMore),
                        _ => bail!("object form requires 'traceEvents' array"),
                    };
                }
                match scan_value2(b, pos, eof)? {
                    Scan::Done(()) => {}
                    Scan::NeedMore => return Ok(Scan::NeedMore),
                }
            }
        }
        None if !eof => Ok(Scan::NeedMore),
        _ => bail!("chrome trace must be an array or object"),
    }
}

/// Whole-buffer wrapper (kept for the scanner unit tests).
#[cfg(test)]
fn find_events_array(b: &[u8]) -> Result<usize> {
    let mut pos = 0usize;
    match find_events_array2(b, &mut pos, true)? {
        Scan::Done(()) => Ok(pos),
        Scan::NeedMore => bail!("chrome trace: truncated document"),
    }
}

/// The next object's (start, end) slice bounds in the events array, or
/// None at `]`.
fn next_event3(b: &[u8], pos: &mut usize, eof: bool) -> Result<Scan<Option<(usize, usize)>>> {
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b',') {
        *pos += 1;
        skip_ws(b, pos);
    }
    match b.get(*pos) {
        Some(b']') => {
            *pos += 1;
            Ok(Scan::Done(None))
        }
        Some(_) => {
            let start = *pos;
            match scan_value2(b, pos, eof)? {
                Scan::Done(()) => Ok(Scan::Done(Some((start, *pos)))),
                Scan::NeedMore => Ok(Scan::NeedMore),
            }
        }
        None => {
            if eof {
                bail!("chrome trace: unterminated events array")
            }
            Ok(Scan::NeedMore)
        }
    }
}

/// Whole-buffer wrapper (kept for the scanner unit tests).
#[cfg(test)]
fn next_event<'a>(b: &'a [u8], pos: &mut usize) -> Result<Option<&'a str>> {
    match next_event3(b, pos, true)? {
        Scan::Done(None) => Ok(None),
        Scan::Done(Some((s, e))) => Ok(Some(std::str::from_utf8(&b[s..e])?)),
        Scan::NeedMore => bail!("chrome trace: unterminated events array"),
    }
}

// -- the sliding disk window the chrome pre-scan runs over -------------------

const CURSOR_CHUNK: usize = 64 * 1024;

/// A sliding window of file bytes: the pre-scan reads forward chunk by
/// chunk and compacts consumed prefixes away, so peak memory is one
/// window (≥ the largest single event) instead of the whole file.
struct DiskCursor {
    file: std::fs::File,
    buf: Vec<u8>,
    /// Absolute file offset of `buf[0]`.
    base: u64,
    eof: bool,
}

impl DiskCursor {
    fn open(path: &Path) -> Result<DiskCursor> {
        let file = std::fs::File::open(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(DiskCursor { file, buf: Vec::new(), base: 0, eof: false })
    }

    /// Append one more chunk; sets `eof` when the file is exhausted.
    fn fill(&mut self) -> Result<()> {
        let old = self.buf.len();
        self.buf.resize(old + CURSOR_CHUNK, 0);
        let n = self.file.read(&mut self.buf[old..])?;
        self.buf.truncate(old + n);
        if n == 0 {
            self.eof = true;
        }
        Ok(())
    }

    fn rel(&self, abs: u64) -> usize {
        (abs - self.base) as usize
    }

    fn slice(&self, a: u64, b: u64) -> &[u8] {
        &self.buf[self.rel(a)..self.rel(b)]
    }

    /// Drop consumed bytes before `abs`, keeping the window bounded.
    fn compact(&mut self, abs: u64) {
        let cut = self.rel(abs);
        if cut > 0 {
            self.buf.drain(..cut);
            self.base = abs;
        }
    }

    /// Run an incremental scanner from absolute offset `start`, reading
    /// more bytes whenever it reports `NeedMore` (retrying from `start`
    /// — items are small, so the rescan is cheap). Returns the absolute
    /// end position and the scanner's output.
    fn scan<T>(
        &mut self,
        start: u64,
        f: impl Fn(&[u8], &mut usize, bool) -> Result<Scan<T>>,
    ) -> Result<(u64, T)> {
        loop {
            let mut pos = self.rel(start);
            match f(&self.buf, &mut pos, self.eof)? {
                Scan::Done(v) => return Ok((self.base + pos as u64, v)),
                Scan::NeedMore => self.fill()?,
            }
        }
    }

    /// The next event's absolute byte bounds, or None at the array's `]`.
    /// `pos` advances past the event (and any separator).
    fn next_event_bounds(&mut self, pos: &mut u64) -> Result<Option<(u64, u64)>> {
        let (end, bounds) = self.scan(*pos, next_event3)?;
        *pos = end;
        Ok(bounds.map(|(s, e)| (self.base + s as u64, self.base + e as u64)))
    }

    /// The byte at absolute offset `abs`, filling as needed; None at EOF.
    fn byte_at(&mut self, abs: u64) -> Result<Option<u8>> {
        while !self.eof && self.rel(abs) >= self.buf.len() {
            self.fill()?;
        }
        Ok(self.buf.get(self.rel(abs)).copied())
    }

    /// Advance `pos` past any whitespace.
    fn skip_ws_at(&mut self, pos: &mut u64) -> Result<()> {
        while let Some(c) = self.byte_at(*pos)? {
            if c.is_ascii_whitespace() {
                *pos += 1;
            } else {
                break;
            }
        }
        Ok(())
    }

    /// Skip one JSON value byte-by-byte with persistent state across
    /// refills, compacting consumed bytes as it goes — so arbitrarily
    /// large values (a 500 MB `stackFrames` before `traceEvents`) are
    /// skipped in O(chunk) memory with no rescans.
    fn skip_value_streaming(&mut self, pos: &mut u64) -> Result<()> {
        let compact_check = |cur: &mut DiskCursor, p: u64| {
            if cur.rel(p) >= 2 * CURSOR_CHUNK {
                cur.compact(p);
            }
        };
        match self.byte_at(*pos)? {
            None => bail!("chrome trace: unexpected end of input"),
            Some(b'"') => {
                *pos += 1;
                loop {
                    compact_check(self, *pos);
                    match self.byte_at(*pos)? {
                        None => bail!("chrome trace: unterminated string"),
                        Some(b'\\') => *pos += 2,
                        Some(b'"') => {
                            *pos += 1;
                            return Ok(());
                        }
                        Some(_) => *pos += 1,
                    }
                }
            }
            Some(b'{') | Some(b'[') => {
                let mut depth = 0usize;
                let mut in_string = false;
                let mut escaped = false;
                loop {
                    compact_check(self, *pos);
                    let Some(c) = self.byte_at(*pos)? else {
                        bail!("chrome trace: unbalanced brackets");
                    };
                    *pos += 1;
                    if in_string {
                        if escaped {
                            escaped = false;
                        } else if c == b'\\' {
                            escaped = true;
                        } else if c == b'"' {
                            in_string = false;
                        }
                        continue;
                    }
                    match c {
                        b'"' => in_string = true,
                        b'{' | b'[' => depth += 1,
                        b'}' | b']' => {
                            depth -= 1;
                            if depth == 0 {
                                return Ok(());
                            }
                        }
                        _ => {}
                    }
                }
            }
            Some(_) => {
                while let Some(c) = self.byte_at(*pos)? {
                    if c == b',' || c == b']' || c == b'}' || c.is_ascii_whitespace() {
                        break;
                    }
                    *pos += 1;
                }
                Ok(())
            }
        }
    }
}

/// Cursor-native events-array locator: like `find_events_array2` but
/// values before the `traceEvents` key are skipped with
/// [`DiskCursor::skip_value_streaming`], so huge prefixes (metadata
/// blobs, stack-frame tables) never sit in the window whole and are
/// never rescanned. Returns the absolute offset just past the `[`.
fn find_events_array_cursor(cur: &mut DiskCursor) -> Result<u64> {
    let mut pos = 0u64;
    cur.skip_ws_at(&mut pos)?;
    match cur.byte_at(pos)? {
        Some(b'[') => Ok(pos + 1),
        Some(b'{') => {
            pos += 1;
            loop {
                cur.compact(pos);
                cur.skip_ws_at(&mut pos)?;
                match cur.byte_at(pos)? {
                    Some(b'"') => {}
                    Some(b'}') | None => bail!("object form requires 'traceEvents' array"),
                    Some(b',') => {
                        pos += 1;
                        continue;
                    }
                    Some(_) => bail!("chrome trace: expected object key"),
                }
                // keys are small: scan them with the windowed scanner
                let (end, ()) = cur.scan(pos, scan_string2)?;
                let is_events = cur.slice(pos + 1, end - 1) == b"traceEvents";
                pos = end;
                cur.skip_ws_at(&mut pos)?;
                if cur.byte_at(pos)? != Some(b':') {
                    bail!("chrome trace: expected ':' after key");
                }
                pos += 1;
                cur.skip_ws_at(&mut pos)?;
                if is_events {
                    if cur.byte_at(pos)? != Some(b'[') {
                        bail!("object form requires 'traceEvents' array");
                    }
                    return Ok(pos + 1);
                }
                cur.skip_value_streaming(&mut pos)?;
            }
        }
        _ => bail!("chrome trace must be an array or object"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{self, GenConfig};
    use crate::readers::read_auto;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pipit_streaming_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Drain a reader and concatenate shard rows back into column vectors
    /// for comparison against the eager read.
    fn drain(r: &mut dyn ShardedReader) -> (Vec<i64>, Vec<i64>, Vec<String>, usize) {
        let mut ts = Vec::new();
        let mut pr = Vec::new();
        let mut names = Vec::new();
        let mut shards = 0;
        while let Some(sh) = r.next_shard().unwrap() {
            assert_eq!(sh.index, shards);
            shards += 1;
            ts.extend_from_slice(sh.trace.timestamps().unwrap());
            pr.extend_from_slice(sh.trace.processes().unwrap());
            let (nm, dict) = sh.trace.events.strs(crate::trace::COL_NAME).unwrap();
            for &c in nm {
                names.push(dict.resolve(c).unwrap_or("").to_string());
            }
        }
        (ts, pr, names, shards)
    }

    fn assert_rows_match(path: &Path) {
        let eager = read_auto(path).unwrap();
        let mut r = open_sharded(path).unwrap();
        if let Some(hint) = r.shard_count_hint() {
            assert!(hint >= 1);
        }
        // the span pre-pass, when available, must agree with the eager
        // trace's range exactly
        if let Some(span) = r.scan_span().unwrap() {
            assert_eq!(span, eager.time_range().unwrap(), "{}", path.display());
        }
        let (ts, pr, names, shards) = drain(r.as_mut());
        assert_eq!(ts, eager.timestamps().unwrap(), "{}", path.display());
        assert_eq!(pr, eager.processes().unwrap(), "{}", path.display());
        let (nm, dict) = eager.events.strs(crate::trace::COL_NAME).unwrap();
        for (i, &c) in nm.iter().enumerate() {
            assert_eq!(names[i], dict.resolve(c).unwrap_or(""), "row {i}");
        }
        assert_eq!(shards, eager.num_processes().unwrap());

        // the task protocol must reproduce the same shards when decoded
        // away from the reader (what the pipelined driver does)
        let mut r = open_sharded(path).unwrap();
        let mut tasks = Vec::new();
        while let Some(t) = r.next_task().unwrap() {
            tasks.push(t);
        }
        let mut ts2 = Vec::new();
        for (k, t) in tasks.into_iter().enumerate() {
            assert_eq!(t.index, k);
            ts2.extend_from_slice(t.decode().unwrap().timestamps().unwrap());
        }
        assert_eq!(ts2, ts, "{}: task decode differs", path.display());
    }

    #[test]
    fn otf2_streams_one_rank_per_shard() {
        let t = gen::generate("laghos", &GenConfig::new(6, 3), 1).unwrap();
        let dir = tmp("otf2_rows");
        let _ = std::fs::remove_dir_all(&dir);
        otf2::write(&t, &dir).unwrap();
        let mut r = open_sharded(&dir).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.shard_count_hint(), Some(6));
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        assert_rows_match(&dir);
    }

    #[test]
    fn csv_streams_canonical_files() {
        let t = gen::generate("gol", &GenConfig::new(4, 3), 1).unwrap();
        let p = tmp("rows.csv");
        csv::write(&t, &p).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        assert_rows_match(&p);
    }

    #[test]
    fn chrome_streams_canonical_files() {
        let t = gen::generate("tortuga", &GenConfig::new(4, 3), 1).unwrap();
        let p = tmp("rows.json");
        chrome::write(&t, &p).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        assert_rows_match(&p);
    }

    #[test]
    fn interleaved_csv_falls_back_to_split_after_load() {
        // processes alternate line-to-line: not streamable, but the
        // fallback must still yield process-aligned shards whose
        // concatenation equals the eager (canonically sorted) read.
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 1\n\
                   0, Enter, main, 0\n\
                   9, Leave, main, 1\n\
                   9, Leave, main, 0\n";
        let p = tmp("interleaved.csv");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(!r.is_streaming());
        // split-after-load still knows the span (trace is resident)
        assert_eq!(r.scan_span().unwrap(), Some((0, 9)));
        assert_rows_match(&p);
    }

    #[test]
    fn descending_process_blocks_fall_back() {
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 1\n\
                   9, Leave, main, 1\n\
                   0, Enter, main, 0\n\
                   9, Leave, main, 0\n";
        let p = tmp("descending.csv");
        std::fs::write(&p, src).unwrap();
        let r = open_sharded(&p).unwrap();
        assert!(!r.is_streaming());
        assert_rows_match(&p);
    }

    #[test]
    fn chrome_object_form_and_metadata_keys() {
        let src = r#"{"displayTimeUnit": "ms", "traceEvents":[
            {"name":"main","ph":"B","ts":0,"pid":0,"tid":0},
            {"name":"main","ph":"E","ts":50,"pid":0,"tid":0},
            {"name":"step","ph":"X","ts":0,"dur":10,"pid":1,"tid":0},
            {"name":"process_name","ph":"M","pid":0,"args":{"name":"axonn"}}
        ], "otherData": {"nested": [1, "a]b", {"x": "}"}]}}"#;
        let p = tmp("objform.json");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        // span covers the X event's end (ts 0 + dur 10µs = 10_000 ns)
        assert_eq!(r.scan_span().unwrap(), Some((0, 50_000)));
        let first = r.next_shard().unwrap().unwrap();
        assert_eq!(first.trace.meta.app, "axonn");
        assert_eq!(first.trace.processes().unwrap(), &[0, 0]);
        let second = r.next_shard().unwrap().unwrap();
        assert_eq!(second.trace.len(), 2); // X -> Enter + Leave
        assert!(r.next_shard().unwrap().is_none());
    }

    #[test]
    fn empty_sources_yield_no_shards() {
        let p = tmp("empty.csv");
        std::fs::write(&p, "Timestamp (ns), Event Type, Name, Process\n").unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.scan_span().unwrap().is_none());
        assert!(r.next_shard().unwrap().is_none());

        let p = tmp("empty.json");
        std::fs::write(&p, "[]").unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.scan_span().unwrap().is_none());
        assert!(r.next_shard().unwrap().is_none());
    }

    #[test]
    fn plan_matches_open_and_is_reusable() {
        // csv: the plan carries block offsets; re-opening from the cached
        // plan yields the same shards as the pre-scanning open
        let t = gen::generate("gol", &GenConfig::new(3, 2), 1).unwrap();
        let p = tmp("plan.csv");
        csv::write(&t, &p).unwrap();
        let plan = plan_sharded(&p).unwrap();
        match &plan {
            StreamPlan::Csv(cp) => {
                assert_eq!(cp.runs(), 3);
                assert_eq!(cp.span, Some(t.time_range().unwrap()));
            }
            other => panic!("expected csv plan, got {other:?}"),
        }
        assert!(plan.is_streaming());
        for _ in 0..2 {
            let mut r = open_planned(&p, &plan).unwrap();
            let mut shards = 0;
            while r.next_shard().unwrap().is_some() {
                shards += 1;
            }
            assert_eq!(shards, 3);
        }

        // chrome: the plan also carries the metadata app name
        let p = tmp("plan.json");
        chrome::write(&t, &p).unwrap();
        match plan_sharded(&p).unwrap() {
            StreamPlan::Chrome(cp) => assert_eq!(cp.runs(), 3),
            other => panic!("expected chrome plan, got {other:?}"),
        }

        // interleaved csv: Fallback, and open_planned still works
        let p = tmp("plan_interleaved.csv");
        std::fs::write(
            &p,
            "Timestamp (ns), Event Type, Name, Process\n\
             0, Enter, main, 1\n\
             0, Enter, main, 0\n\
             9, Leave, main, 1\n\
             9, Leave, main, 0\n",
        )
        .unwrap();
        let plan = plan_sharded(&p).unwrap();
        assert_eq!(plan, StreamPlan::Fallback);
        assert!(!plan.is_streaming());
        let r = open_planned(&p, &plan).unwrap();
        assert!(!r.is_streaming());
    }

    #[test]
    fn otf2_plan_needs_no_prescan() {
        let t = gen::generate("amg", &GenConfig::new(2, 2), 1).unwrap();
        let dir = tmp("plan_otf2");
        let _ = std::fs::remove_dir_all(&dir);
        otf2::write(&t, &dir).unwrap();
        assert_eq!(plan_sharded(&dir).unwrap(), StreamPlan::Otf2);
    }

    #[test]
    fn pre_extrema_otf2_archives_have_no_span_but_still_stream() {
        // the checked-in fixture predates the defs.bin extrema section:
        // scan_span must degrade to None (legacy buffered binning), not
        // error, and shards must still decode
        let fix = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/tiny_otf2");
        let mut r = open_sharded(&fix).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), None);
        assert_rows_match(&fix);
    }

    #[test]
    fn serial_decode_adapter_delegates_and_decodes_inline() {
        let t = gen::generate("gol", &GenConfig::new(3, 2), 1).unwrap();
        let p = tmp("serial.csv");
        csv::write(&t, &p).unwrap();
        let mut inner = open_sharded(&p).unwrap();
        let mut r = SerialDecode::new(inner.as_mut());
        assert!(r.is_streaming());
        assert_eq!(r.shard_count_hint(), Some(3));
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        let (ts, _, _, shards) = drain(&mut r);
        assert_eq!(shards, 3);
        assert_eq!(ts, t.timestamps().unwrap());
    }

    #[test]
    fn span_prescan_survives_bad_timestamps_as_none() {
        // an unparsable timestamp forfeits only the span pre-pass; the
        // plan still streams and the decode reports the real error
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 0\n\
                   oops, Leave, main, 0\n";
        let p = tmp("badts.csv");
        std::fs::write(&p, src).unwrap();
        let plan = plan_sharded(&p).unwrap();
        match &plan {
            StreamPlan::Csv(cp) => {
                assert_eq!(cp.runs(), 1);
                assert_eq!(cp.span, None);
            }
            other => panic!("expected csv plan, got {other:?}"),
        }
        let mut r = open_planned(&p, &plan).unwrap();
        let err = r.next_shard().unwrap_err();
        assert!(err.to_string().contains("bad timestamp"), "{err}");
    }

    /// A final line with no trailing newline is a complete row: block
    /// byte ranges end at the file length, so the census row counts and
    /// span extrema must include it.
    #[test]
    fn csv_without_trailing_newline_streams_exactly() {
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 0\n\
                   5, Leave, main, 0\n\
                   1, Enter, main, 1\n\
                   7, Leave, main, 1";
        let p = tmp("no_trailing_newline.csv");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), Some((0, 7)));
        let census = r.census().expect("csv pre-scan carries a census");
        let rows: Vec<u64> = census.blocks.iter().map(|b| b.rows).collect();
        assert_eq!(rows, vec![2, 2]);
        assert_eq!(census.blocks[1].span, Some((1, 7)));
        assert_rows_match(&p);
    }

    /// CRLF line endings: `read_line` byte counts include the `\r`, so
    /// block offsets stay exact, and field trimming strips the `\r`
    /// from the last column in both the pre-scan and the decode.
    #[test]
    fn crlf_line_endings_stream_exactly() {
        let src = "Timestamp (ns), Event Type, Name, Process\r\n\
                   0, Enter, main, 0\r\n\
                   5, Leave, main, 0\r\n\
                   1, Enter, main, 1\r\n\
                   7, Leave, main, 1\r\n";
        let p = tmp("crlf.csv");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), Some((0, 7)));
        let census = r.census().expect("csv pre-scan carries a census");
        let rows: Vec<u64> = census.blocks.iter().map(|b| b.rows).collect();
        assert_eq!(rows, vec![2, 2]);
        assert_rows_match(&p);
    }

    /// Multi-byte UTF-8 names in a file much larger than the cursor
    /// chunk: the sliding window lands mid-character and mid-event many
    /// times, and the byte-based scanner must still produce exact event
    /// bounds, census row counts, and span extrema.
    #[test]
    fn chrome_multibyte_names_across_cursor_chunk_boundaries() {
        let name = "संगणना_φase"; // 2- and 3-byte UTF-8 sequences
        let mut src = String::from("[\n");
        let mut first = true;
        for pid in 0..3 {
            for k in 0..400i64 {
                for (ph, ts) in [("B", k * 10), ("E", k * 10 + 5)] {
                    if !first {
                        src.push(',');
                    }
                    first = false;
                    src.push_str(&format!(
                        "{{\"name\":\"{name}{k}\",\"ph\":\"{ph}\",\
                         \"ts\":{ts},\"pid\":{pid},\"tid\":0}}\n"
                    ));
                }
            }
        }
        src.push(']');
        assert!(src.len() > 2 * CURSOR_CHUNK, "fixture must span several chunks");
        let p = tmp("multibyte.json");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        // chrome ts is in microseconds: 3995 µs -> 3_995_000 ns
        assert_eq!(r.scan_span().unwrap(), Some((0, 3_995_000)));
        let census = r.census().expect("chrome pre-scan carries a census");
        let rows: Vec<u64> = census.blocks.iter().map(|b| b.rows).collect();
        assert_eq!(rows, vec![800, 800, 800]);
        assert_rows_match(&p);
    }

    /// The pre-scan census must reproduce the engine census exactly —
    /// same function names in the same first-seen segment order, same
    /// integer-ns exclusive totals — and its block / channel / message
    /// sections must agree with the decoded rows, on every census-
    /// carrying format.
    #[test]
    fn prescan_census_matches_engine_census() {
        let t = gen::generate("laghos", &GenConfig::new(5, 4), 1).unwrap();
        let dir = tmp("census_dir");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let csv_p = dir.join("c.csv");
        csv::write(&t, &csv_p).unwrap();
        let json_p = dir.join("c.json");
        chrome::write(&t, &json_p).unwrap();
        let otf2_p = dir.join("c_otf2");
        otf2::write(&t, &otf2_p).unwrap();

        for p in [&csv_p, &json_p, &otf2_p] {
            let eager = read_auto(p).unwrap();
            let segs =
                crate::analysis::time_profile::exclusive_segments(&mut eager.clone()).unwrap();
            let engine = crate::analysis::time_profile::census(&segs);
            let (_, dict) = eager.events.strs(crate::trace::COL_NAME).unwrap();
            let want_names: Vec<String> = engine
                .codes
                .iter()
                .map(|&c| dict.resolve(c).unwrap_or("").to_string())
                .collect();
            let want_totals: Vec<i64> =
                engine.totals.iter().map(|&v| v as i64).collect();

            let r = open_sharded(p).unwrap();
            let census = r.census().unwrap_or_else(|| {
                panic!("{}: census must be available", p.display())
            });
            let funcs = census.funcs.as_ref().unwrap();
            assert_eq!(funcs.names, want_names, "{}", p.display());
            assert_eq!(funcs.exc_ns, want_totals, "{}", p.display());

            // block metadata agrees with the decoded rows
            assert_eq!(census.total_rows() as usize, eager.len(), "{}", p.display());
            assert_eq!(census.span(), Some(eager.time_range().unwrap()), "{}", p.display());

            // channel census totals equal the matcher's endpoint counts
            let mm = crate::analysis::match_messages(&eager).unwrap();
            let chans = census.channels.as_ref().unwrap();
            let sends: u64 = chans.iter().map(|c| c.sends).sum();
            let recvs: u64 = chans.iter().map(|c| c.recvs).sum();
            assert_eq!(sends as usize, mm.sends.len(), "{}", p.display());
            assert_eq!(recvs as usize, mm.recvs.len(), "{}", p.display());
        }
    }

    #[test]
    fn prescan_census_forfeits_on_undecodable_rows_but_still_streams() {
        // an unknown event type makes the decode error; the census must
        // be forfeited while the plan still streams (the decode owns the
        // error message)
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 0\n\
                   5, Explode, main, 0\n\
                   9, Leave, main, 0\n";
        let p = tmp("census_forfeit.csv");
        std::fs::write(&p, src).unwrap();
        match plan_sharded(&p).unwrap() {
            StreamPlan::Csv(cp) => {
                assert_eq!(cp.runs(), 1);
                assert!(cp.census.is_none(), "undecodable row must forfeit the census");
                // the timestamps all parsed, so the span survives
                assert_eq!(cp.span, Some((0, 9)));
            }
            other => panic!("expected csv plan, got {other:?}"),
        }
    }

    #[test]
    fn no_census_adapter_hides_the_census_only() {
        let t = gen::generate("gol", &GenConfig::new(3, 2), 1).unwrap();
        let p = tmp("nocensus.csv");
        csv::write(&t, &p).unwrap();
        let mut inner = open_sharded(&p).unwrap();
        assert!(inner.census().is_some());
        let mut r = NoCensus::new(inner.as_mut());
        assert!(r.census().is_none());
        assert!(!r.census_corrupt());
        assert!(r.is_streaming());
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        let (ts, _, _, shards) = drain(&mut r);
        assert_eq!(shards, 3);
        assert_eq!(ts, t.timestamps().unwrap());
    }

    #[test]
    fn scanner_handles_strings_with_brackets() {
        let b = br#"[{"name":"f(a, b]","ph":"B","ts":0,"pid":0}]"#;
        let mut pos = find_events_array(b).unwrap();
        let first = next_event(b, &mut pos).unwrap().unwrap();
        assert!(first.contains("f(a, b]"));
        assert!(next_event(b, &mut pos).unwrap().is_none());
    }

    #[test]
    fn disk_cursor_scans_across_chunk_boundaries() {
        // force events to straddle fill boundaries by padding with
        // whitespace; the cursor pre-scan must slice them identically
        let mut src = String::from("[");
        for i in 0..40 {
            if i > 0 {
                src.push(',');
            }
            src.push_str(&" ".repeat(4000));
            src.push_str(&format!(
                r#"{{"name":"f{i}","ph":"X","ts":{},"dur":5,"pid":{}}}"#,
                i * 10,
                i / 10
            ));
        }
        src.push(']');
        let p = tmp("chunked.json");
        std::fs::write(&p, &src).unwrap();
        let plan = chrome_prescan(&p).unwrap().expect("streamable");
        assert_eq!(plan.runs(), 4);
        assert_eq!(plan.span, Some((0, 390_000 + 5_000)));
        let eager = read_auto(&p).unwrap();
        assert_rows_match(&p);
        assert_eq!(eager.num_processes().unwrap(), 4);
    }
}
