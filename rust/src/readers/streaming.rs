//! Streaming shard-at-a-time ingest: [`ShardedReader`] yields
//! process-aligned [`TraceShard`]s incrementally, so the analysis driver
//! in [`crate::exec::stream`] never materializes the whole trace — peak
//! memory is bounded by O(workers × shard + results) instead of O(trace).
//!
//! | format      | strategy                                               |
//! |-------------|--------------------------------------------------------|
//! | otf2-dir    | one rank file decoded per shard (the flagship path)    |
//! | csv         | line stream from disk; shard per process boundary      |
//! | chrome json | incremental object scanner over the raw text (the file |
//! |             | bytes stay resident, but never the parsed JSON tree or |
//! |             | row set — the dominant costs of the eager reader)      |
//! | hpctoolkit  | split-after-load fallback ([`SplitReader`])            |
//! | projections | split-after-load fallback ([`SplitReader`])            |
//!
//! The csv / chrome readers require process blocks to appear contiguous
//! and ascending (what every writer in this crate emits, and what
//! per-rank trace formats produce naturally); a cheap pre-scan verifies
//! this and falls back to eager-load + [`SplitReader`] otherwise, so
//! `open_sharded` accepts everything `read_auto` accepts. The pre-scan
//! is split from reader construction ([`plan_sharded`] →
//! [`StreamPlan`] → [`open_planned`]) so sessions re-opening the same
//! source per analysis verify it once; fallbacks are surfaced to
//! callers via `StreamStats::fallback` rather than silently holding the
//! whole trace.
//!
//! Determinism: concatenating shard rows in yield order reproduces the
//! canonical (Process, Thread, Timestamp) row order of the eager reader
//! exactly — the property every order-stable merge in
//! [`crate::exec::stream`] relies on to stay bit-identical with eager
//! `read_auto` + sequential analysis.

use super::{chrome, csv, otf2};
use crate::df::Interner;
use crate::trace::{Trace, TraceBuilder, TraceMeta};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One process-aligned slice of a trace, in canonical row order.
pub struct TraceShard {
    /// Position in the stream (0-based); shard order is row order.
    pub index: usize,
    pub trace: Trace,
}

/// Incremental, process-aligned trace reader.
pub trait ShardedReader {
    /// Yield the next shard in canonical row order, or None at end.
    fn next_shard(&mut self) -> Result<Option<TraceShard>>;

    /// Number of shards this reader will yield, when known up front.
    fn shard_count_hint(&self) -> Option<usize>;

    /// True when shards decode incrementally from the source (bounded
    /// memory); false for split-after-load fallbacks, which hold the
    /// whole trace while yielding.
    fn is_streaming(&self) -> bool;

    /// For split-after-load fallbacks: recover the already-loaded trace
    /// instead of throwing the parse away (consumes the reader).
    /// Streaming readers return None. Callers that would otherwise
    /// re-open the source repeatedly (e.g. a session keeping a
    /// non-streamable entry) use this to avoid paying a full re-read per
    /// analysis.
    fn into_eager_trace(self: Box<Self>) -> Option<Trace> {
        None
    }
}

/// The cached result of the streamability pre-scan. Sessions keep one
/// per stream-backed entry so repeated routed analyses skip the
/// re-verification — the csv pre-scan parses every line's Process field
/// and the chrome pre-scan walks every event object, roughly half the
/// per-analysis parse work for those formats.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamPlan {
    /// OTF2-sim directory: one rank file per shard, no pre-scan needed.
    Otf2,
    /// Canonically-ordered csv: `runs` process blocks stream from disk.
    Csv { runs: usize },
    /// Canonically-ordered chrome json: `runs` pid blocks, plus the
    /// application name the pre-scan lifted from metadata records.
    Chrome { runs: usize, app: String },
    /// Not streamable (hpctoolkit / projections / interleaved files):
    /// eager load + [`SplitReader`].
    Fallback,
}

impl StreamPlan {
    /// Will [`open_planned`] yield a truly streaming reader?
    pub fn is_streaming(&self) -> bool {
        !matches!(self, StreamPlan::Fallback)
    }
}

/// Run only the streamability pre-scan, without opening a reader —
/// mirrors [`super::read_auto`]'s format detection.
pub fn plan_sharded(path: &Path) -> Result<StreamPlan> {
    if path.is_dir() {
        if path.join("defs.bin").exists() {
            return Ok(StreamPlan::Otf2);
        }
        if path.join("meta.db").exists() {
            return Ok(StreamPlan::Fallback);
        }
        for entry in std::fs::read_dir(path)? {
            let p = entry?.path();
            if p.extension().and_then(|e| e.to_str()) == Some("sts") {
                return Ok(StreamPlan::Fallback);
            }
        }
        bail!("unrecognized trace directory: {}", path.display());
    }
    match path.extension().and_then(|e| e.to_str()).unwrap_or("") {
        "csv" => Ok(match csv_prescan(path)? {
            Some(runs) => StreamPlan::Csv { runs },
            None => StreamPlan::Fallback,
        }),
        "json" => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            Ok(match chrome_prescan(&text) {
                Some((runs, app)) => StreamPlan::Chrome { runs, app },
                None => StreamPlan::Fallback,
            })
        }
        _ => bail!("unrecognized trace file: {}", path.display()),
    }
}

/// Open a reader for a previously computed [`StreamPlan`], skipping the
/// pre-scan (sessions cache the plan per entry and re-open cheaply per
/// analysis).
pub fn open_planned(path: &Path, plan: &StreamPlan) -> Result<Box<dyn ShardedReader>> {
    match plan {
        StreamPlan::Otf2 => Ok(Box::new(Otf2ShardedReader::open(path)?)),
        StreamPlan::Csv { runs } => csv_stream(path, *runs),
        StreamPlan::Chrome { runs, app } => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            chrome_stream(path, text, *runs, app.clone())
        }
        StreamPlan::Fallback => {
            Ok(Box::new(SplitReader::new(super::read_auto(path)?)?))
        }
    }
}

/// Open `path` as a sharded reader with format auto-detection, mirroring
/// [`super::read_auto`]: plan + open in one call. Chrome files read
/// their text once and hand it straight to the stream (sessions going
/// through [`plan_sharded`] + [`open_planned`] instead pay one read per
/// open but skip the pre-scan walk).
pub fn open_sharded(path: &Path) -> Result<Box<dyn ShardedReader>> {
    if !path.is_dir() && path.extension().and_then(|e| e.to_str()) == Some("json") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        return match chrome_prescan(&text) {
            Some((runs, app)) => chrome_stream(path, text, runs, app),
            None => Ok(Box::new(SplitReader::new(super::read_auto(path)?)?)),
        };
    }
    open_planned(path, &plan_sharded(path)?)
}

// -- split-after-load fallback ---------------------------------------------

/// Fallback reader: an eagerly-loaded trace yielded one process at a
/// time. Memory is O(trace) during iteration; row order and per-shard
/// alignment are identical to the truly-streaming readers, so every
/// downstream merge behaves the same.
pub struct SplitReader {
    trace: Trace,
    ranges: Vec<(usize, usize)>,
    next: usize,
}

impl SplitReader {
    pub fn new(trace: Trace) -> Result<Self> {
        let shards = crate::exec::process_shards(&trace, usize::MAX)?;
        Ok(SplitReader { trace, ranges: shards.ranges, next: 0 })
    }
}

impl ShardedReader for SplitReader {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        if self.next >= self.ranges.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let trace = crate::exec::subtrace(&self.trace, self.ranges[index])?;
        Ok(Some(TraceShard { index, trace }))
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.ranges.len())
    }

    fn is_streaming(&self) -> bool {
        false
    }

    fn into_eager_trace(self: Box<Self>) -> Option<Trace> {
        Some(self.trace)
    }
}

// -- otf2: one rank file per shard -----------------------------------------

/// OTF2-sim streaming reader: global defs are read once; each
/// `rank_<r>.bin` stream decodes on demand into one shard. This is true
/// bounded-memory ingest — only one rank's events exist at a time, and
/// the shared `Arc` dictionaries keep name codes identical across shards.
pub struct Otf2ShardedReader {
    dir: PathBuf,
    defs: otf2::Defs,
    etype_dict: Arc<Interner>,
    etypes: otf2::EtypeCodes,
    next: usize,
}

impl Otf2ShardedReader {
    pub fn open(dir: &Path) -> Result<Self> {
        let defs = otf2::read_defs(dir)?;
        let (etype_dict, etypes) = otf2::etype_codes();
        Ok(Otf2ShardedReader { dir: dir.to_path_buf(), defs, etype_dict, etypes, next: 0 })
    }
}

impl ShardedReader for Otf2ShardedReader {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        if self.next >= self.defs.ranks.len() {
            return Ok(None);
        }
        let index = self.next;
        self.next += 1;
        let rank = self.defs.ranks[index];
        let sh = otf2::read_rank(&self.dir, rank, &self.defs, &self.etypes)?;
        let table = otf2::shard_table(sh, &self.defs.names, &self.etype_dict)?;
        let meta = TraceMeta {
            format: "otf2".into(),
            source: self.dir.display().to_string(),
            app: self.defs.app.clone(),
        };
        Ok(Some(TraceShard { index, trace: Trace::new(table, meta) }))
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.defs.ranks.len())
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- csv: line stream with process-boundary shard emission ------------------

/// Open a CSV trace whose pre-scan verified `runs` contiguous, ascending
/// process blocks — the canonical order this crate's writer emits.
/// (The pre-scan itself lives in [`plan_sharded`]; interleaved files get
/// a [`StreamPlan::Fallback`] instead.)
fn csv_stream(path: &Path, runs: usize) -> Result<Box<dyn ShardedReader>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().context("empty csv")??;
    let h = csv::parse_header(&header)?;
    Ok(Box::new(CsvStream {
        lines,
        header: h,
        meta: csv::csv_meta(path),
        pending: None,
        line_no: 1,
        index: 0,
        shards_total: runs,
    }))
}

/// Streamability pre-scan: parse only the Process field of every line and
/// check blocks are contiguous + ascending. `Ok(Some(runs))` when
/// streamable; `Ok(None)` requests the eager fallback (which also owns
/// producing proper errors for malformed files).
fn csv_prescan(path: &Path) -> Result<Option<usize>> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = match lines.next() {
        Some(l) => l?,
        None => return Ok(None),
    };
    let Ok(h) = csv::parse_header(&header) else {
        return Ok(None);
    };
    let mut runs = 0usize;
    let mut last: Option<i64> = None;
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let Some(p) = csv::parse_proc(&h, &line) else {
            return Ok(None);
        };
        match last {
            Some(q) if p == q => {}
            Some(q) if p > q => {
                runs += 1;
                last = Some(p);
            }
            Some(_) => return Ok(None), // process reappeared: not grouped
            None => {
                runs = 1;
                last = Some(p);
            }
        }
    }
    Ok(Some(runs))
}

struct CsvStream {
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    header: csv::CsvHeader,
    meta: TraceMeta,
    pending: Option<csv::CsvRow>,
    /// 1-based file line number of the last line read (header = 1).
    line_no: usize,
    index: usize,
    shards_total: usize,
}

impl ShardedReader for CsvStream {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        let mut b = TraceBuilder::new();
        b.set_meta(self.meta.clone());
        let mut cur: Option<i64> = None;
        if let Some(row) = self.pending.take() {
            cur = Some(row.proc);
            csv::apply_row(&mut b, &row);
        }
        for line in self.lines.by_ref() {
            let line = line?;
            self.line_no += 1;
            if line.trim().is_empty() {
                continue;
            }
            let row = csv::parse_row(&self.header, &line, self.line_no)?;
            match cur {
                Some(p) if row.proc != p => {
                    self.pending = Some(row);
                    let index = self.index;
                    self.index += 1;
                    return Ok(Some(TraceShard { index, trace: b.finish() }));
                }
                _ => {
                    cur = Some(row.proc);
                    csv::apply_row(&mut b, &row);
                }
            }
        }
        if cur.is_none() {
            return Ok(None);
        }
        let index = self.index;
        self.index += 1;
        Ok(Some(TraceShard { index, trace: b.finish() }))
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.shards_total)
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- chrome: incremental object scanner -------------------------------------

/// Open a Chrome Trace JSON file whose pre-scan verified `runs`
/// contiguous, ascending pid blocks. Events are scanned one object at a
/// time — the whole-document JSON tree and full row set (typically the
/// dominant memory costs of the eager reader, several times the file
/// size) never exist. The raw file text does stay resident for the
/// stream's lifetime, so peak memory here is O(file bytes + workers ×
/// shard + results); a disk-cursor scanner is the ROADMAP follow-up.
/// (The pre-scan itself lives in [`plan_sharded`], which also lifts
/// `app` from metadata records; interleaved files get a
/// [`StreamPlan::Fallback`] instead.)
fn chrome_stream(
    path: &Path,
    text: String,
    runs: usize,
    app: String,
) -> Result<Box<dyn ShardedReader>> {
    let pos = find_events_array(text.as_bytes())?;
    Ok(Box::new(ChromeStream {
        text,
        pos,
        meta: TraceMeta {
            format: "chrome".into(),
            source: path.display().to_string(),
            app,
        },
        pending: None,
        event_idx: 0,
        index: 0,
        shards_total: runs,
        done: false,
    }))
}

/// Pre-scan: walk every event object, collect the application name from
/// metadata records, and check that row-producing events keep pids
/// contiguous + ascending. None requests the eager fallback (including
/// for malformed files, whose errors the eager reader reports properly).
fn chrome_prescan(text: &str) -> Option<(usize, String)> {
    let b = text.as_bytes();
    let mut pos = find_events_array(b).ok()?;
    let mut runs = 0usize;
    let mut last: Option<i64> = None;
    let mut app = String::new();
    loop {
        let slice = match next_event(b, &mut pos) {
            Ok(Some(s)) => s,
            Ok(None) => break,
            Err(_) => return None,
        };
        let e = Json::parse(slice).ok()?;
        if !chrome::is_row_event(&e) {
            if e.get_str("ph") == Some("M") && e.get_str("name") == Some("process_name") {
                if let Some(n) = e.get("args").and_then(|a| a.get_str("name")) {
                    app = n.to_string();
                }
            }
            continue;
        }
        let pid = chrome::event_pid(&e);
        match last {
            Some(q) if pid == q => {}
            Some(q) if pid > q => {
                runs += 1;
                last = Some(pid);
            }
            Some(_) => return None,
            None => {
                runs = 1;
                last = Some(pid);
            }
        }
    }
    Some((runs, app))
}

struct ChromeStream {
    text: String,
    pos: usize,
    meta: TraceMeta,
    pending: Option<(usize, Json)>,
    event_idx: usize,
    index: usize,
    shards_total: usize,
    /// Set once the events array closes — the scanner must not run past
    /// it into trailing document keys (object-form files).
    done: bool,
}

impl ShardedReader for ChromeStream {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        if self.done && self.pending.is_none() {
            return Ok(None);
        }
        let mut b = TraceBuilder::new();
        b.set_meta(self.meta.clone());
        let mut cur: Option<i64> = None;
        if let Some((i, e)) = self.pending.take() {
            cur = Some(chrome::event_pid(&e));
            chrome::apply_event(&mut b, &e, i)?;
        }
        while !self.done {
            let parsed = match next_event(self.text.as_bytes(), &mut self.pos)? {
                None => None,
                Some(slice) => Some(Json::parse(slice)?),
            };
            let Some(e) = parsed else {
                self.done = true;
                break;
            };
            let i = self.event_idx;
            self.event_idx += 1;
            if !chrome::is_row_event(&e) {
                continue; // metadata: already folded into meta by the pre-scan
            }
            let pid = chrome::event_pid(&e);
            match cur {
                Some(p) if pid != p => {
                    self.pending = Some((i, e));
                    let index = self.index;
                    self.index += 1;
                    return Ok(Some(TraceShard { index, trace: b.finish() }));
                }
                _ => {
                    cur = Some(pid);
                    chrome::apply_event(&mut b, &e, i)?;
                }
            }
        }
        if cur.is_none() {
            return Ok(None);
        }
        let index = self.index;
        self.index += 1;
        Ok(Some(TraceShard { index, trace: b.finish() }))
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.shards_total)
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- minimal incremental JSON scanning --------------------------------------
//
// Just enough lexing to slice one `{...}` event out of the (possibly
// huge) events array; each slice then goes through the full
// `Json::parse`, so event *interpretation* is byte-for-byte the eager
// reader's.

fn skip_ws(b: &[u8], pos: &mut usize) {
    while let Some(c) = b.get(*pos) {
        if c.is_ascii_whitespace() {
            *pos += 1;
        } else {
            break;
        }
    }
}

fn scan_string(b: &[u8], pos: &mut usize) -> Result<()> {
    *pos += 1; // opening quote
    while let Some(&c) = b.get(*pos) {
        match c {
            b'\\' => *pos += 2,
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            _ => *pos += 1,
        }
    }
    bail!("chrome trace: unterminated string")
}

/// Advance past one JSON value of any kind (balanced braces / brackets,
/// string-aware).
fn scan_value(b: &[u8], pos: &mut usize) -> Result<()> {
    match b.get(*pos) {
        Some(b'"') => scan_string(b, pos),
        Some(b'{') | Some(b'[') => {
            let mut depth = 0usize;
            loop {
                match b.get(*pos) {
                    None => bail!("chrome trace: unbalanced brackets"),
                    Some(b'"') => {
                        scan_string(b, pos)?;
                        continue;
                    }
                    Some(b'{') | Some(b'[') => depth += 1,
                    Some(b'}') | Some(b']') => {
                        depth -= 1;
                        if depth == 0 {
                            *pos += 1;
                            return Ok(());
                        }
                    }
                    Some(_) => {}
                }
                *pos += 1;
            }
        }
        Some(_) => {
            while let Some(&c) = b.get(*pos) {
                if c == b',' || c == b']' || c == b'}' || c.is_ascii_whitespace() {
                    break;
                }
                *pos += 1;
            }
            Ok(())
        }
        None => bail!("chrome trace: unexpected end of input"),
    }
}

/// Position just past the `[` of the events array: the document root for
/// array-form files, the `traceEvents` value for object-form files.
fn find_events_array(b: &[u8]) -> Result<usize> {
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    match b.get(pos) {
        Some(b'[') => Ok(pos + 1),
        Some(b'{') => {
            pos += 1;
            loop {
                skip_ws(b, &mut pos);
                match b.get(pos) {
                    Some(b'"') => {}
                    Some(b'}') | None => bail!("object form requires 'traceEvents' array"),
                    Some(b',') => {
                        pos += 1;
                        continue;
                    }
                    Some(_) => bail!("chrome trace: expected object key"),
                }
                let kstart = pos;
                scan_string(b, &mut pos)?;
                let key = &b[kstart + 1..pos - 1];
                skip_ws(b, &mut pos);
                if b.get(pos) != Some(&b':') {
                    bail!("chrome trace: expected ':' after key");
                }
                pos += 1;
                skip_ws(b, &mut pos);
                if key == b"traceEvents" {
                    if b.get(pos) != Some(&b'[') {
                        bail!("object form requires 'traceEvents' array");
                    }
                    return Ok(pos + 1);
                }
                scan_value(b, &mut pos)?;
            }
        }
        _ => bail!("chrome trace must be an array or object"),
    }
}

/// The next object slice in the events array, or None at `]`.
fn next_event<'a>(b: &'a [u8], pos: &mut usize) -> Result<Option<&'a str>> {
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b',') {
        *pos += 1;
        skip_ws(b, pos);
    }
    match b.get(*pos) {
        Some(b']') => {
            *pos += 1;
            Ok(None)
        }
        Some(_) => {
            let start = *pos;
            scan_value(b, pos)?;
            Ok(Some(std::str::from_utf8(&b[start..*pos])?))
        }
        None => bail!("chrome trace: unterminated events array"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{self, GenConfig};
    use crate::readers::read_auto;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pipit_streaming_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Drain a reader and concatenate shard rows back into column vectors
    /// for comparison against the eager read.
    fn drain(r: &mut dyn ShardedReader) -> (Vec<i64>, Vec<i64>, Vec<String>, usize) {
        let mut ts = Vec::new();
        let mut pr = Vec::new();
        let mut names = Vec::new();
        let mut shards = 0;
        while let Some(sh) = r.next_shard().unwrap() {
            assert_eq!(sh.index, shards);
            shards += 1;
            ts.extend_from_slice(sh.trace.timestamps().unwrap());
            pr.extend_from_slice(sh.trace.processes().unwrap());
            let (nm, dict) = sh.trace.events.strs(crate::trace::COL_NAME).unwrap();
            for &c in nm {
                names.push(dict.resolve(c).unwrap_or("").to_string());
            }
        }
        (ts, pr, names, shards)
    }

    fn assert_rows_match(path: &Path) {
        let eager = read_auto(path).unwrap();
        let mut r = open_sharded(path).unwrap();
        if let Some(hint) = r.shard_count_hint() {
            assert!(hint >= 1);
        }
        let (ts, pr, names, shards) = drain(r.as_mut());
        assert_eq!(ts, eager.timestamps().unwrap(), "{}", path.display());
        assert_eq!(pr, eager.processes().unwrap(), "{}", path.display());
        let (nm, dict) = eager.events.strs(crate::trace::COL_NAME).unwrap();
        for (i, &c) in nm.iter().enumerate() {
            assert_eq!(names[i], dict.resolve(c).unwrap_or(""), "row {i}");
        }
        assert_eq!(shards, eager.num_processes().unwrap());
    }

    #[test]
    fn otf2_streams_one_rank_per_shard() {
        let t = gen::generate("laghos", &GenConfig::new(6, 3), 1).unwrap();
        let dir = tmp("otf2_rows");
        let _ = std::fs::remove_dir_all(&dir);
        otf2::write(&t, &dir).unwrap();
        let r = open_sharded(&dir).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.shard_count_hint(), Some(6));
        assert_rows_match(&dir);
    }

    #[test]
    fn csv_streams_canonical_files() {
        let t = gen::generate("gol", &GenConfig::new(4, 3), 1).unwrap();
        let p = tmp("rows.csv");
        csv::write(&t, &p).unwrap();
        let r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_rows_match(&p);
    }

    #[test]
    fn chrome_streams_canonical_files() {
        let t = gen::generate("tortuga", &GenConfig::new(4, 3), 1).unwrap();
        let p = tmp("rows.json");
        chrome::write(&t, &p).unwrap();
        let r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        assert_rows_match(&p);
    }

    #[test]
    fn interleaved_csv_falls_back_to_split_after_load() {
        // processes alternate line-to-line: not streamable, but the
        // fallback must still yield process-aligned shards whose
        // concatenation equals the eager (canonically sorted) read.
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 1\n\
                   0, Enter, main, 0\n\
                   9, Leave, main, 1\n\
                   9, Leave, main, 0\n";
        let p = tmp("interleaved.csv");
        std::fs::write(&p, src).unwrap();
        let r = open_sharded(&p).unwrap();
        assert!(!r.is_streaming());
        assert_rows_match(&p);
    }

    #[test]
    fn descending_process_blocks_fall_back() {
        let src = "Timestamp (ns), Event Type, Name, Process\n\
                   0, Enter, main, 1\n\
                   9, Leave, main, 1\n\
                   0, Enter, main, 0\n\
                   9, Leave, main, 0\n";
        let p = tmp("descending.csv");
        std::fs::write(&p, src).unwrap();
        let r = open_sharded(&p).unwrap();
        assert!(!r.is_streaming());
        assert_rows_match(&p);
    }

    #[test]
    fn chrome_object_form_and_metadata_keys() {
        let src = r#"{"displayTimeUnit": "ms", "traceEvents":[
            {"name":"main","ph":"B","ts":0,"pid":0,"tid":0},
            {"name":"main","ph":"E","ts":50,"pid":0,"tid":0},
            {"name":"step","ph":"X","ts":0,"dur":10,"pid":1,"tid":0},
            {"name":"process_name","ph":"M","pid":0,"args":{"name":"axonn"}}
        ], "otherData": {"nested": [1, "a]b", {"x": "}"}]}}"#;
        let p = tmp("objform.json");
        std::fs::write(&p, src).unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.is_streaming());
        let first = r.next_shard().unwrap().unwrap();
        assert_eq!(first.trace.meta.app, "axonn");
        assert_eq!(first.trace.processes().unwrap(), &[0, 0]);
        let second = r.next_shard().unwrap().unwrap();
        assert_eq!(second.trace.len(), 2); // X -> Enter + Leave
        assert!(r.next_shard().unwrap().is_none());
    }

    #[test]
    fn empty_sources_yield_no_shards() {
        let p = tmp("empty.csv");
        std::fs::write(&p, "Timestamp (ns), Event Type, Name, Process\n").unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.next_shard().unwrap().is_none());

        let p = tmp("empty.json");
        std::fs::write(&p, "[]").unwrap();
        let mut r = open_sharded(&p).unwrap();
        assert!(r.next_shard().unwrap().is_none());
    }

    #[test]
    fn plan_matches_open_and_is_reusable() {
        // csv: the plan carries the run count; re-opening from the cached
        // plan yields the same shards as the pre-scanning open
        let t = gen::generate("gol", &GenConfig::new(3, 2), 1).unwrap();
        let p = tmp("plan.csv");
        csv::write(&t, &p).unwrap();
        let plan = plan_sharded(&p).unwrap();
        assert_eq!(plan, StreamPlan::Csv { runs: 3 });
        assert!(plan.is_streaming());
        for _ in 0..2 {
            let mut r = open_planned(&p, &plan).unwrap();
            let mut shards = 0;
            while r.next_shard().unwrap().is_some() {
                shards += 1;
            }
            assert_eq!(shards, 3);
        }

        // chrome: the plan also carries the metadata app name
        let p = tmp("plan.json");
        chrome::write(&t, &p).unwrap();
        match plan_sharded(&p).unwrap() {
            StreamPlan::Chrome { runs, .. } => assert_eq!(runs, 3),
            other => panic!("expected chrome plan, got {other:?}"),
        }

        // interleaved csv: Fallback, and open_planned still works
        let p = tmp("plan_interleaved.csv");
        std::fs::write(
            &p,
            "Timestamp (ns), Event Type, Name, Process\n\
             0, Enter, main, 1\n\
             0, Enter, main, 0\n\
             9, Leave, main, 1\n\
             9, Leave, main, 0\n",
        )
        .unwrap();
        let plan = plan_sharded(&p).unwrap();
        assert_eq!(plan, StreamPlan::Fallback);
        assert!(!plan.is_streaming());
        let r = open_planned(&p, &plan).unwrap();
        assert!(!r.is_streaming());
    }

    #[test]
    fn otf2_plan_needs_no_prescan() {
        let t = gen::generate("amg", &GenConfig::new(2, 2), 1).unwrap();
        let dir = tmp("plan_otf2");
        let _ = std::fs::remove_dir_all(&dir);
        otf2::write(&t, &dir).unwrap();
        assert_eq!(plan_sharded(&dir).unwrap(), StreamPlan::Otf2);
    }

    #[test]
    fn scanner_handles_strings_with_brackets() {
        let b = br#"[{"name":"f(a, b]","ph":"B","ts":0,"pid":0}]"#;
        let mut pos = find_events_array(b).unwrap();
        let first = next_event(b, &mut pos).unwrap().unwrap();
        assert!(first.contains("f(a, b]"));
        assert!(next_event(b, &mut pos).unwrap().is_none());
    }
}
