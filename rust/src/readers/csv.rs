//! CSV trace reader / writer (paper Fig. 1).
//!
//! Header names follow the canonical schema; `Timestamp (s)` is accepted
//! and scaled to ns. Only `Timestamp`, `Event Type`, `Name`, `Process` are
//! required — remaining columns default to null / 0. Fields containing
//! commas (C++ signatures like `f(const A &, int)`) are double-quoted per
//! RFC 4180.

use crate::df::NULL_I64;
use crate::trace::*;
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Parsed CSV header: column positions plus the timestamp scale.
pub(crate) struct CsvHeader {
    idx_ts: usize,
    ts_scale: i64,
    idx_type: usize,
    idx_name: usize,
    pub(crate) idx_proc: usize,
    idx_thread: Option<usize>,
    idx_partner: Option<usize>,
    idx_size: Option<usize>,
    idx_tag: Option<usize>,
}

/// Parse the header line into column positions.
pub(crate) fn parse_header(header: &str) -> Result<CsvHeader> {
    let cols = split_csv_line(header);
    let mut idx_ts = None;
    let mut ts_scale = 1i64;
    let (mut idx_type, mut idx_name, mut idx_proc) = (None, None, None);
    let (mut idx_thread, mut idx_partner, mut idx_size, mut idx_tag) = (None, None, None, None);
    for (i, c) in cols.iter().enumerate() {
        match c.trim() {
            "Timestamp (ns)" => idx_ts = Some(i),
            "Timestamp (s)" => {
                idx_ts = Some(i);
                ts_scale = 1_000_000_000;
            }
            "Event Type" => idx_type = Some(i),
            "Name" => idx_name = Some(i),
            "Process" => idx_proc = Some(i),
            "Thread" => idx_thread = Some(i),
            "Partner" => idx_partner = Some(i),
            "Msg Size" => idx_size = Some(i),
            "Tag" => idx_tag = Some(i),
            other => bail!("unknown csv column '{other}'"),
        }
    }
    let (idx_ts, idx_type, idx_name, idx_proc) = match (idx_ts, idx_type, idx_name, idx_proc) {
        (Some(a), Some(b), Some(c), Some(d)) => (a, b, c, d),
        _ => bail!("csv must have Timestamp, Event Type, Name, Process columns"),
    };
    Ok(CsvHeader {
        idx_ts,
        ts_scale,
        idx_type,
        idx_name,
        idx_proc,
        idx_thread,
        idx_partner,
        idx_size,
        idx_tag,
    })
}

/// One parsed data row, ready to feed a [`TraceBuilder`].
pub(crate) struct CsvRow {
    pub(crate) ts: i64,
    pub(crate) proc: i64,
    thread: i64,
    partner: i64,
    size: i64,
    tag: i64,
    event: CsvEvent,
}

enum CsvEvent {
    Enter(String),
    Leave(String),
    Send,
    Recv,
    Instant(String),
}

/// Parse one data line. `display_line` is the 1-based file line number
/// used in error messages.
pub(crate) fn parse_row(h: &CsvHeader, line: &str, display_line: usize) -> Result<CsvRow> {
    let f = split_csv_line(line);
    let get = |i: Option<usize>| i.and_then(|i| f.get(i)).map(|s| s.trim());
    let ts: f64 = get(Some(h.idx_ts))
        .context("missing ts")?
        .parse()
        .with_context(|| format!("line {display_line}: bad timestamp"))?;
    let ts = (ts * h.ts_scale as f64).round() as i64;
    let etype = get(Some(h.idx_type)).context("missing type")?;
    let name = get(Some(h.idx_name)).context("missing name")?;
    let proc: i64 = get(Some(h.idx_proc))
        .context("missing process")?
        .parse()
        .with_context(|| format!("line {display_line}: bad process"))?;
    let thread: i64 = get(h.idx_thread).and_then(|s| s.parse().ok()).unwrap_or(0);
    let partner: i64 = get(h.idx_partner)
        .filter(|s| !s.is_empty())
        .and_then(|s| s.parse().ok())
        .unwrap_or(NULL_I64);
    let size: i64 = get(h.idx_size)
        .filter(|s| !s.is_empty())
        .and_then(|s| s.parse().ok())
        .unwrap_or(NULL_I64);
    let tag: i64 = get(h.idx_tag)
        .filter(|s| !s.is_empty())
        .and_then(|s| s.parse().ok())
        .unwrap_or(NULL_I64);
    let event = match etype {
        ENTER => CsvEvent::Enter(name.to_string()),
        LEAVE => CsvEvent::Leave(name.to_string()),
        INSTANT => match name {
            SEND_EVENT => CsvEvent::Send,
            RECV_EVENT => CsvEvent::Recv,
            _ => CsvEvent::Instant(name.to_string()),
        },
        other => bail!("line {display_line}: unknown event type '{other}'"),
    };
    Ok(CsvRow { ts, proc, thread, partner, size, tag, event })
}

/// Feed one parsed row into a builder.
pub(crate) fn apply_row(b: &mut TraceBuilder, r: &CsvRow) {
    match &r.event {
        CsvEvent::Enter(n) => b.enter(r.proc, r.thread, r.ts, n),
        CsvEvent::Leave(n) => b.leave(r.proc, r.thread, r.ts, n),
        CsvEvent::Send => b.send(r.proc, r.thread, r.ts, r.partner, r.size, r.tag),
        CsvEvent::Recv => b.recv(r.proc, r.thread, r.ts, r.partner, r.size, r.tag),
        CsvEvent::Instant(n) => b.instant(r.proc, r.thread, r.ts, n),
    }
}

/// Split one data line into fields for [`prescan_row`] — the caller
/// keeps the buffer so the parsed row can borrow names out of it
/// (the per-line pre-scan allocates nothing beyond the split itself).
pub(crate) fn split_fields(line: &str) -> Vec<String> {
    split_csv_line(line)
}

/// What the streaming pre-scan extracts from one data line — everything
/// the census needs, parsed with [`parse_row`]'s exact semantics but
/// leniently: fields whose failure would make the *decode* error are
/// reported as `None` (the decode owns producing the error message; the
/// pre-scan merely forfeits the sections that depended on them).
pub(crate) struct PrescanRow<'a> {
    pub(crate) proc: i64,
    pub(crate) thread: i64,
    /// ns timestamp; None when unparsable (span + census forfeited).
    pub(crate) ts: Option<i64>,
    /// Interpreted event; None when the event type is unknown (census
    /// forfeited — the decode will reject this line).
    pub(crate) event: Option<PrescanEvent<'a>>,
}

/// The census-relevant interpretation of one line, mirroring
/// [`CsvEvent`]: message payload fields fall back to null exactly like
/// [`parse_row`] does. Names borrow from the caller's field buffer.
pub(crate) enum PrescanEvent<'a> {
    Enter(&'a str),
    Leave(&'a str),
    Send { partner: i64, size: i64, tag: i64 },
    Recv { partner: i64, size: i64, tag: i64 },
    Instant,
}

/// Parse one pre-split data line ([`split_fields`]) for the pre-scan.
/// None when the Process field is missing or unparsable (the line is
/// not groupable — the pre-scan falls back to the eager reader, which
/// owns the error).
pub(crate) fn prescan_row<'a>(h: &CsvHeader, f: &'a [String]) -> Option<PrescanRow<'a>> {
    let get = |i: Option<usize>| i.and_then(|i| f.get(i)).map(|s| s.trim());
    let proc: i64 = get(Some(h.idx_proc))?.parse().ok()?;
    let thread: i64 = get(h.idx_thread).and_then(|s| s.parse().ok()).unwrap_or(0);
    let ts = get(Some(h.idx_ts))
        .and_then(|s| s.parse::<f64>().ok())
        .map(|ts| (ts * h.ts_scale as f64).round() as i64);
    let opt = |i: Option<usize>| {
        get(i)
            .filter(|s| !s.is_empty())
            .and_then(|s| s.parse().ok())
            .unwrap_or(NULL_I64)
    };
    let event = match (get(Some(h.idx_type)), get(Some(h.idx_name))) {
        (Some(ENTER), Some(name)) => Some(PrescanEvent::Enter(name)),
        (Some(LEAVE), Some(name)) => Some(PrescanEvent::Leave(name)),
        (Some(INSTANT), Some(name)) => Some(match name {
            SEND_EVENT => PrescanEvent::Send {
                partner: opt(h.idx_partner),
                size: opt(h.idx_size),
                tag: opt(h.idx_tag),
            },
            RECV_EVENT => PrescanEvent::Recv {
                partner: opt(h.idx_partner),
                size: opt(h.idx_size),
                tag: opt(h.idx_tag),
            },
            _ => PrescanEvent::Instant,
        }),
        _ => None,
    };
    Some(PrescanRow { proc, thread, ts, event })
}

/// The provenance metadata every CSV read (eager or streamed) attaches.
pub(crate) fn csv_meta(path: &Path) -> TraceMeta {
    TraceMeta {
        format: "csv".into(),
        source: path.display().to_string(),
        app: String::new(),
    }
}

/// Read a CSV trace file.
pub fn read(path: &Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header = lines.next().context("empty csv")?;
    let h = parse_header(header)?;
    let mut b = TraceBuilder::new();
    b.set_meta(csv_meta(path));
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = parse_row(&h, line, lineno + 2)?;
        apply_row(&mut b, &row);
    }
    Ok(b.finish())
}

/// Write a trace as CSV (the inverse of [`read`]).
pub fn write(trace: &Trace, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "Timestamp (ns), Event Type, Name, Process, Thread, Partner, Msg Size, Tag"
    )?;
    let ts = trace.events.i64s(COL_TS)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let th = trace.events.i64s(COL_THREAD)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let ms = trace.events.i64s(COL_MSG_SIZE)?;
    let tg = trace.events.i64s(COL_TAG)?;
    let opt = |v: i64| {
        if v == NULL_I64 {
            String::new()
        } else {
            v.to_string()
        }
    };
    for i in 0..trace.len() {
        writeln!(
            w,
            "{}, {}, {}, {}, {}, {}, {}, {}",
            ts[i],
            edict.resolve(et[i]).unwrap_or(""),
            quote_csv(ndict.resolve(nm[i]).unwrap_or("")),
            pr[i],
            th[i],
            opt(pa[i]),
            opt(ms[i]),
            opt(tg[i]),
        )?;
    }
    Ok(())
}

/// Quote a field if it contains characters that break bare CSV.
fn quote_csv(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Split one CSV line honoring double quotes.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' => in_quotes = true,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::validate_nesting;

    #[test]
    fn reads_paper_fig1_sample() {
        let csv = "Timestamp (s), Event Type, Name, Process\n\
                   0, Enter, main(), 0\n\
                   1, Enter, foo(), 0\n\
                   3, Enter, MPI_Send, 0\n\
                   5, Leave, MPI_Send, 0\n\
                   8, Enter, baz(), 0\n\
                   18, Leave, baz(), 0\n\
                   25, Leave, foo(), 0\n\
                   100, Leave, main(), 0\n";
        let dir = std::env::temp_dir().join("pipit_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("foo-bar.csv");
        std::fs::write(&p, csv).unwrap();
        let t = read(&p).unwrap();
        assert_eq!(t.len(), 8);
        // seconds scaled to ns, exactly as the paper's figure shows
        assert_eq!(t.timestamps().unwrap()[1], 1_000_000_000);
        assert_eq!(validate_nesting(&t).unwrap(), 3);
    }

    #[test]
    fn roundtrip_with_messages_and_commas() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "f(const A &, int)");
        b.enter(0, 0, 1, "MPI_Send");
        b.send(0, 0, 2, 1, 4096, 3);
        b.leave(0, 0, 5, "MPI_Send");
        b.leave(0, 0, 9, "f(const A &, int)");
        b.enter(1, 0, 0, "MPI_Recv");
        b.recv(1, 0, 6, 0, 4096, 3);
        b.leave(1, 0, 7, "MPI_Recv");
        let t = b.finish();

        let dir = std::env::temp_dir().join("pipit_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.csv");
        write(&t, &p).unwrap();
        let t2 = read(&p).unwrap();
        assert_eq!(t2.len(), t.len());
        assert_eq!(t2.timestamps().unwrap(), t.timestamps().unwrap());
        assert_eq!(
            t2.events.i64s(COL_MSG_SIZE).unwrap(),
            t.events.i64s(COL_MSG_SIZE).unwrap()
        );
        let (nm, dict) = t2.events.strs(COL_NAME).unwrap();
        assert_eq!(dict.resolve(nm[0]), Some("f(const A &, int)"));
    }

    #[test]
    fn split_handles_quotes() {
        assert_eq!(split_csv_line("a,b,c"), vec!["a", "b", "c"]);
        assert_eq!(
            split_csv_line(r#"1,"f(a, b)",2"#),
            vec!["1", "f(a, b)", "2"]
        );
        assert_eq!(split_csv_line(r#""say ""hi""""#), vec![r#"say "hi""#]);
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("pipit_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "Nope, Columns\n1,2\n").unwrap();
        assert!(read(&p).is_err());
    }
}
