//! Chrome Trace Viewer JSON reader / writer.
//!
//! This is the format Nsight Systems exports and PyTorch Profiler emits
//! natively, so one reader covers both rows of the paper's format list.
//! Supported phases: `B`/`E` (duration begin/end), `X` (complete event =
//! begin+end with `dur`), `i`/`I` (instant), `M` (metadata: process_name).
//! Timestamps are microseconds (float) → scaled to ns. Message payloads
//! travel in `args` (`partner`, `size`, `tag`) on instant events named
//! `MpiSend`/`MpiRecv` (also recognized: `ncclSend`/`ncclRecv` records).

use crate::df::NULL_I64;
use crate::trace::*;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;

/// Read a Chrome Trace JSON file.
pub fn read(path: &Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let root = Json::parse(&text)?;
    let events = match &root {
        Json::Arr(a) => a.as_slice(),
        Json::Obj(_) => root
            .get("traceEvents")
            .and_then(|v| v.as_arr())
            .context("object form requires 'traceEvents' array")?,
        _ => bail!("chrome trace must be an array or object"),
    };

    let mut b = TraceBuilder::new();
    let mut app = String::new();
    // X events become Enter+Leave; builder sorts canonically at finish.
    for (i, e) in events.iter().enumerate() {
        if let Some(name) = apply_event(&mut b, e, i)? {
            app = name;
        }
    }
    b.set_meta(TraceMeta {
        format: "chrome".into(),
        source: path.display().to_string(),
        app,
    });
    Ok(b.finish())
}

/// Feed one Chrome trace event into a builder. `i` is the event index
/// (error messages only). Returns the application name when the event is
/// a `process_name` metadata record. Shared by the eager reader above and
/// the streaming reader in [`super::streaming`], so both interpret every
/// phase identically.
pub(crate) fn apply_event(b: &mut TraceBuilder, e: &Json, i: usize) -> Result<Option<String>> {
    let ph = e.get_str("ph").unwrap_or("X");
    let name = e.get_str("name").unwrap_or("<unnamed>");
    let pid = e.get_f64("pid").unwrap_or(0.0) as i64;
    let tid = e.get_f64("tid").unwrap_or(0.0) as i64;
    let ts_us = e.get_f64("ts").unwrap_or(0.0);
    let ts = (ts_us * 1000.0).round() as i64;
    match ph {
        "B" => b.enter(pid, tid, ts, name),
        "E" => b.leave(pid, tid, ts, name),
        "X" => {
            let dur = e
                .get_f64("dur")
                .with_context(|| format!("event {i}: X without dur"))?;
            let te = ts + (dur * 1000.0).round() as i64;
            b.enter(pid, tid, ts, name);
            b.leave(pid, tid, te, name);
        }
        "i" | "I" | "R" => {
            let args = e.get("args");
            let geti = |k: &str| {
                args.and_then(|a| a.get_f64(k))
                    .map(|v| v as i64)
                    .unwrap_or(NULL_I64)
            };
            match name {
                SEND_EVENT | "ncclSend" => {
                    b.send(pid, tid, ts, geti("partner"), geti("size"), geti("tag"))
                }
                RECV_EVENT | "ncclRecv" => {
                    b.recv(pid, tid, ts, geti("partner"), geti("size"), geti("tag"))
                }
                _ => b.instant(pid, tid, ts, name),
            }
        }
        "M" => {
            if name == "process_name" {
                if let Some(n) = e.get("args").and_then(|a| a.get_str("name")) {
                    return Ok(Some(n.to_string()));
                }
            }
        }
        // counters, flow, async events: out of scope, skipped
        _ => {}
    }
    Ok(None)
}

/// Does this event produce trace rows (as opposed to metadata / skipped
/// phases)? The streaming reader uses this to decide which events count
/// toward process-grouping and shard boundaries.
pub(crate) fn is_row_event(e: &Json) -> bool {
    matches!(
        e.get_str("ph").unwrap_or("X"),
        "B" | "E" | "X" | "i" | "I" | "R"
    )
}

/// The pid a row event belongs to (0 when absent, matching the reader).
pub(crate) fn event_pid(e: &Json) -> i64 {
    e.get_f64("pid").unwrap_or(0.0) as i64
}

/// The tid a row event belongs to (0 when absent, matching the reader).
pub(crate) fn event_tid(e: &Json) -> i64 {
    e.get_f64("tid").unwrap_or(0.0) as i64
}

/// The (partner, size, tag) payload of an instant message event, with
/// [`apply_event`]'s exact null fallbacks — used by the streaming
/// pre-scan's channel / message census.
pub(crate) fn event_msg_args(e: &Json) -> (i64, i64, i64) {
    let args = e.get("args");
    let geti = |k: &str| {
        args.and_then(|a| a.get_f64(k))
            .map(|v| v as i64)
            .unwrap_or(NULL_I64)
    };
    (geti("partner"), geti("size"), geti("tag"))
}

/// The ns timestamps a row event contributes to the trace: its `ts`,
/// plus the end timestamp for `X` events — the exact arithmetic of
/// [`apply_event`], used by the streaming span pre-pass. The end is None
/// when `dur` is missing (the full decode owns that error).
pub(crate) fn row_event_times(e: &Json) -> (i64, Option<i64>) {
    let ts = (e.get_f64("ts").unwrap_or(0.0) * 1000.0).round() as i64;
    let te = if e.get_str("ph").unwrap_or("X") == "X" {
        e.get_f64("dur").map(|d| ts + (d * 1000.0).round() as i64)
    } else {
        None
    };
    (ts, te)
}

/// Write a trace as Chrome Trace JSON (B/E + instant events).
pub fn write(trace: &Trace, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let ts = trace.events.i64s(COL_TS)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let th = trace.events.i64s(COL_THREAD)?;
    let pa = trace.events.i64s(COL_PARTNER)?;
    let ms = trace.events.i64s(COL_MSG_SIZE)?;
    let tg = trace.events.i64s(COL_TAG)?;

    writeln!(w, "{{\"traceEvents\":[")?;
    let mut first = true;
    for i in 0..trace.len() {
        let etype = edict.resolve(et[i]).unwrap_or("");
        let name = ndict.resolve(nm[i]).unwrap_or("");
        let ph = match etype {
            ENTER => "B",
            LEAVE => "E",
            INSTANT => "i",
            _ => continue,
        };
        let mut fields = vec![
            ("name", s(name)),
            ("ph", s(ph)),
            ("ts", num(ts[i] as f64 / 1000.0)),
            ("pid", num(pr[i] as f64)),
            ("tid", num(th[i] as f64)),
        ];
        if ph == "i" && pa[i] != NULL_I64 {
            fields.push((
                "args",
                obj(vec![
                    ("partner", num(pa[i] as f64)),
                    ("size", num(ms[i] as f64)),
                    ("tag", num(if tg[i] == NULL_I64 { 0.0 } else { tg[i] as f64 })),
                ]),
            ));
        }
        if !first {
            writeln!(w, ",")?;
        }
        first = false;
        write!(w, "{}", obj(fields).dumps())?;
    }
    writeln!(w, "\n]}}")?;
    Ok(())
}

/// Convenience: serialize a list of events as PyTorch-profiler-style JSON
/// (array form, X events) — exercised by tests to prove both JSON shapes
/// parse identically.
pub fn write_array_form(trace: &Trace, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let ts = trace.events.i64s(COL_TS)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, ndict) = trace.events.strs(COL_NAME)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let th = trace.events.i64s(COL_THREAD)?;

    // Convert matched Enter/Leave to X events with dur.
    let match_rows = crate::analysis::match_caller_callee::matching_events(trace)?;
    let mut items: Vec<Json> = Vec::new();
    for i in 0..trace.len() {
        let etype = edict.resolve(et[i]).unwrap_or("");
        if etype == ENTER {
            let j = match_rows[i];
            if j < 0 {
                continue;
            }
            let dur_us = (ts[j as usize] - ts[i]) as f64 / 1000.0;
            items.push(obj(vec![
                ("name", s(ndict.resolve(nm[i]).unwrap_or(""))),
                ("ph", s("X")),
                ("ts", num(ts[i] as f64 / 1000.0)),
                ("dur", num(dur_us)),
                ("pid", num(pr[i] as f64)),
                ("tid", num(th[i] as f64)),
            ]));
        }
    }
    write!(w, "{}", arr(items).dumps())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::builder::validate_nesting;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pipit_chrome_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn reads_object_form_with_b_e_events() {
        let src = r#"{"traceEvents":[
            {"name":"main","ph":"B","ts":0,"pid":0,"tid":0},
            {"name":"gemm","ph":"B","ts":10.5,"pid":0,"tid":0},
            {"name":"gemm","ph":"E","ts":20.5,"pid":0,"tid":0},
            {"name":"main","ph":"E","ts":100,"pid":0,"tid":0},
            {"name":"process_name","ph":"M","pid":0,"args":{"name":"axonn"}}
        ]}"#;
        let p = tmp("obj.json");
        std::fs::write(&p, src).unwrap();
        let t = read(&p).unwrap();
        assert_eq!(t.len(), 4);
        assert_eq!(t.meta.app, "axonn");
        assert_eq!(t.timestamps().unwrap()[1], 10_500); // µs -> ns
        validate_nesting(&t).unwrap();
    }

    #[test]
    fn reads_array_form_with_x_events() {
        let src = r#"[
            {"name":"step","ph":"X","ts":0,"dur":100,"pid":1,"tid":0},
            {"name":"kernel","ph":"X","ts":10,"dur":30,"pid":1,"tid":0}
        ]"#;
        let p = tmp("arr.json");
        std::fs::write(&p, src).unwrap();
        let t = read(&p).unwrap();
        assert_eq!(t.len(), 4); // two X -> two Enter+Leave pairs
        assert_eq!(validate_nesting(&t).unwrap(), 2);
    }

    #[test]
    fn roundtrip_preserves_messages() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "MPI_Send");
        b.send(0, 0, 500, 1, 2048, 9);
        b.leave(0, 0, 1000, "MPI_Send");
        let t = b.finish();
        let p = tmp("rt.json");
        write(&t, &p).unwrap();
        let t2 = read(&p).unwrap();
        assert_eq!(t2.len(), 3);
        assert_eq!(t2.events.i64s(COL_PARTNER).unwrap()[1], 1);
        assert_eq!(t2.events.i64s(COL_MSG_SIZE).unwrap()[1], 2048);
    }

    #[test]
    fn rejects_x_without_dur() {
        let p = tmp("bad.json");
        std::fs::write(&p, r#"[{"name":"a","ph":"X","ts":0}]"#).unwrap();
        assert!(read(&p).is_err());
    }
}
