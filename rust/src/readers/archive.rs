//! Pipit archive: the persistent indexed trace format — convert any
//! reader's output once, query it forever with pure seeks.
//!
//! ```text
//! <dir>/index.bin   magic, version, trace meta, block table
//!                   (byte offset / rows / span / per-column chunk
//!                   framing per process-aligned block), embedded
//!                   TraceCensus with per-block sub-censuses
//! <dir>/blocks.bin  concatenated zlib-compressed column chunks
//! ```
//!
//! Each block holds one process run's rows, column-major, as **seven
//! independently framed chunks** (version 2) in fixed order — ts, type,
//! name, thread, partner, msg size, tag — each separately compressed
//! and checksummed, with its (length, raw length, crc) recorded in the
//! block's index entry. A name chunk carries its local dictionary (so
//! blocks serialize in parallel with no shared state); timestamps are
//! delta-zigzag varints, event types one byte each, i64 columns zigzag
//! varints (`NULL_I64` survives zigzag — no clamping, the decoded rows
//! are bit-identical to the source reader's). Version-1 archives (one
//! monolithic chunk per block) still open and decode unchanged.
//!
//! Reopening ([`ArchiveBlocks`]) parses only `index.bin`: block offsets,
//! spans and the full census are known **before any shard decodes** —
//! zero pre-scan, which is what finally gives the split-after-load
//! formats (hpctoolkit, projections) true streaming after a one-time
//! conversion (see `exec::stream::write_archive`).
//!
//! On top of that, [`ArchiveBlocks::open_with`] takes an
//! [`AccessPlan`] and plans the read: blocks whose span misses the
//! plan's time window — or whose `BlockDetail` sub-census *proves* the
//! channel-traffic predicate can't match — are pruned before any shard
//! is scheduled; surviving v2 blocks inflate only the column chunks the
//! plan names (skipped columns materialize as schema defaults); and the
//! remaining byte-ranges are read ahead in small batches
//! (`ARCHIVE_READAHEAD_BLOCKS`, default 4) so decode work overlaps I/O.
//! Pruning is conservative: a block is only skipped when the index
//! proves it irrelevant, so census-absent or corrupt-census archives
//! simply fall back to full scans and results stay bit-identical.
//!
//! Corruption degrades deterministically, never panics: a damaged
//! `index.bin` (magic / version / truncated block table) is an open
//! error; a bit-flipped block chunk fails its FNV checksum at decode
//! (zlib alone can miss flips in stored blocks); a damaged census
//! section degrades to "census absent" exactly like the otf2 trailer.

use super::census::{
    fnv32, BlockCensus, BlockDetail, CensusAccum, ChannelCensus, FuncTotals, MsgCensus,
    TraceCensus, CENSUS_VERSION,
};
use super::otf2::{get_uvarint, put_uvarint};
use super::streaming::{AccessPlan, ColumnSet, Predicate, PruneStats, ShardTask, ShardedReader, TraceShard};
use crate::df::{Column, Interner, Table, NULL_I64};
use crate::trace::*;
use anyhow::{bail, Context, Result};
use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

/// The block index / metadata file (its presence marks an archive dir).
pub(crate) const INDEX_FILE: &str = "index.bin";
/// The concatenated compressed block chunks.
pub(crate) const BLOCKS_FILE: &str = "blocks.bin";

const MAGIC: &[u8; 8] = b"PIPARCH1";

/// Current archive format version. Version 1 (one monolithic chunk per
/// block) is still readable; version 2 frames each block as seven
/// per-column chunks so a planned read can inflate a subset. Anything
/// newer is a typed [`VersionMismatch`] open error (the format is
/// self-contained — "convert once" means a stale archive should be
/// reconverted, not half-read).
pub const ARCHIVE_VERSION: u64 = 2;

/// Per-block column chunks in file order: ts, type, name, thread,
/// partner, msg size, tag. The indices line up with the bit positions
/// of [`ColumnSet`], so a plan's column mask indexes the chunk table
/// directly.
const NUM_CHUNKS: usize = 7;
/// Chunk index of the event-type column (1 byte per row — its raw
/// length doubles as a row-count cross-check at index parse).
const CHUNK_ET: usize = 1;

/// Typed open error for an archive written by an unsupported format
/// version — callers can downcast to tell "reconvert this" apart from
/// real corruption.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VersionMismatch {
    pub found: u64,
    pub have: u64,
}

impl std::fmt::Display for VersionMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "archive version {} unsupported (have {})", self.found, self.have)
    }
}

impl std::error::Error for VersionMismatch {}

/// Census-section flag bytes in `index.bin` (mirrors the otf2 trailer).
const CENSUS_MARKER: u8 = 0xC6;
const CENSUS_ABSENT: u8 = 0x00;

// chunk event-type bytes
const ET_ENTER: u8 = 0;
const ET_LEAVE: u8 = 1;
const ET_INSTANT: u8 = 2;

// -- zigzag (i64 <-> u64, NULL_I64-safe) -----------------------------------

#[inline]
pub(crate) fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub(crate) fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

fn put_span(buf: &mut Vec<u8>, span: Option<(i64, i64)>) {
    match span {
        Some((lo, hi)) => {
            buf.push(1);
            put_uvarint(buf, zigzag(lo));
            put_uvarint(buf, (hi - lo) as u64);
        }
        None => buf.push(0),
    }
}

fn get_span(buf: &[u8], pos: &mut usize) -> Result<Option<(i64, i64)>> {
    let flag = *buf.get(*pos).context("truncated span record")?;
    *pos += 1;
    match flag {
        0 => Ok(None),
        1 => {
            let lo = unzigzag(get_uvarint(buf, pos)?);
            let width = get_uvarint(buf, pos)? as i64;
            Ok(Some((lo, lo + width)))
        }
        other => bail!("bad span flag {other}"),
    }
}

// -- block chunks -----------------------------------------------------------

/// One column chunk's framing inside a block: compressed length, raw
/// (decompressed) length, and FNV-1a of the compressed bytes — verified
/// at decode, so a bit flip is a deterministic per-shard error, never
/// silent data.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ColChunk {
    pub(crate) len: u64,
    pub(crate) raw_len: u64,
    pub(crate) crc: u32,
}

/// One process-aligned block, compressed and ready to append to
/// `blocks.bin` (plus the facts its index entry records).
pub(crate) struct BlockChunk {
    pub(crate) proc: i64,
    pub(crate) rows: u64,
    pub(crate) span: Option<(i64, i64)>,
    /// All seven column chunks concatenated in file order.
    pub(crate) compressed: Vec<u8>,
    /// Per-column framing (one entry per chunk, same order).
    pub(crate) cols: Vec<ColChunk>,
}

/// Everything one decoded shard contributes to the archive: its blocks,
/// its slice of the census, and the source meta (stored verbatim so the
/// reopened archive is indistinguishable from the source reader).
pub(crate) struct ShardPayload {
    pub(crate) meta: TraceMeta,
    pub(crate) chunks: Vec<BlockChunk>,
    pub(crate) census: Option<TraceCensus>,
}

struct Cols<'a> {
    ts: &'a [i64],
    et: &'a [u32],
    nm: &'a [u32],
    th: &'a [i64],
    pa: &'a [i64],
    ms: &'a [i64],
    tg: &'a [i64],
    edict: &'a Interner,
    ndict: &'a Interner,
}

/// Serialize one decoded shard into archive blocks (split at process
/// transitions) and its census slice — the parallel map half of
/// conversion; the driver folds payloads in shard order.
pub(crate) fn shard_payload(t: &Trace) -> Result<ShardPayload> {
    let c = Cols {
        ts: t.events.i64s(COL_TS)?,
        et: t.events.strs(COL_TYPE)?.0,
        nm: t.events.strs(COL_NAME)?.0,
        th: t.events.i64s(COL_THREAD)?,
        pa: t.events.i64s(COL_PARTNER)?,
        ms: t.events.i64s(COL_MSG_SIZE)?,
        tg: t.events.i64s(COL_TAG)?,
        edict: t.events.strs(COL_TYPE)?.1,
        ndict: t.events.strs(COL_NAME)?.1,
    };
    let pr = t.events.i64s(COL_PROC)?;
    let enter = c.edict.code_of(ENTER);
    let leave = c.edict.code_of(LEAVE);
    let send_nm = c.ndict.code_of(SEND_EVENT);
    let recv_nm = c.ndict.code_of(RECV_EVENT);

    // the census is fed exactly as the routed analyses will see the
    // decoded rows, one end_block per archive block, so the embedded
    // census agrees bit-for-bit with the reopened stream
    let mut accum = CensusAccum::new();
    let mut chunks = Vec::new();
    let n = t.len();
    let mut start = 0usize;
    while start < n {
        let p = pr[start];
        let mut end = start + 1;
        while end < n && pr[end] == p {
            end += 1;
        }
        for i in start..end {
            accum.row(c.ts[i]);
            let code = Some(c.et[i]);
            if code == enter {
                accum.enter(c.th[i], c.ts[i], c.ndict.resolve(c.nm[i]).unwrap_or(""));
            } else if code == leave {
                accum.leave(c.th[i], c.ts[i], c.ndict.resolve(c.nm[i]).unwrap_or(""));
            }
            // endpoint accounting is name-based and independent of the
            // event type, exactly like the message matcher and the comm
            // analyses — so an empty channel sub-census *proves* a block
            // contributes nothing to them (the planner's pruning rule)
            if Some(c.nm[i]) == send_nm {
                accum.send(p, c.pa[i], c.tg[i], c.ms[i]);
            } else if Some(c.nm[i]) == recv_nm {
                accum.recv(p, c.pa[i], c.tg[i], c.ms[i]);
            }
        }
        accum.end_block(p);
        chunks.push(encode_block(&c, p, start, end)?);
        start = end;
    }
    Ok(ShardPayload { meta: t.meta.clone(), chunks, census: accum.finish() })
}

fn encode_block(c: &Cols, proc: i64, start: usize, end: usize) -> Result<BlockChunk> {
    let enter = c.edict.code_of(ENTER);
    let leave = c.edict.code_of(LEAVE);
    let instant = c.edict.code_of(INSTANT);
    let nrows = end - start;

    // ts chunk: zigzag deltas (timestamps restart per thread within a
    // block, so deltas can be negative — zigzag, not plain uvarint)
    let mut ts_p = Vec::with_capacity(nrows * 2);
    let mut prev = 0i64;
    let mut span: Option<(i64, i64)> = None;
    for i in start..end {
        let t = c.ts[i];
        put_uvarint(&mut ts_p, zigzag(t.wrapping_sub(prev)));
        prev = t;
        span = Some(match span {
            Some((lo, hi)) => (lo.min(t), hi.max(t)),
            None => (t, t),
        });
    }

    // event-type chunk: one byte per row
    let mut et_p = Vec::with_capacity(nrows);
    for i in start..end {
        let code = Some(c.et[i]);
        et_p.push(if code == enter {
            ET_ENTER
        } else if code == leave {
            ET_LEAVE
        } else if code == instant {
            ET_INSTANT
        } else {
            bail!(
                "cannot archive event type {:?} at row {i}",
                c.edict.resolve(c.et[i]).unwrap_or("?")
            )
        });
    }

    // name chunk: local dictionary in first-use order (blocks are
    // self-contained, so the parallel map stage shares no dictionary
    // state), then one code per row
    let mut local_of: HashMap<u32, u32> = HashMap::new();
    let mut local_names: Vec<&str> = Vec::new();
    let mut codes = Vec::with_capacity(nrows);
    for i in start..end {
        let code = match local_of.entry(c.nm[i]) {
            std::collections::hash_map::Entry::Occupied(e) => *e.get(),
            std::collections::hash_map::Entry::Vacant(v) => {
                let code = local_names.len() as u32;
                local_names.push(c.ndict.resolve(c.nm[i]).unwrap_or(""));
                v.insert(code);
                code
            }
        };
        codes.push(code);
    }
    let mut nm_p = Vec::with_capacity(nrows * 2 + 64);
    put_uvarint(&mut nm_p, local_names.len() as u64);
    for s in &local_names {
        put_uvarint(&mut nm_p, s.len() as u64);
        nm_p.extend_from_slice(s.as_bytes());
    }
    for &code in &codes {
        put_uvarint(&mut nm_p, code as u64);
    }

    // i64 chunks: zigzag varints
    let i64_chunk = |col: &[i64]| {
        let mut p = Vec::with_capacity(nrows * 2);
        for i in start..end {
            put_uvarint(&mut p, zigzag(col[i]));
        }
        p
    };
    let th_p = i64_chunk(c.th);
    let pa_p = i64_chunk(c.pa);
    let ms_p = i64_chunk(c.ms);
    let tg_p = i64_chunk(c.tg);

    // compress each chunk independently so a planned read can inflate a
    // subset; frame each with (len, raw_len, crc) for the index entry
    let mut compressed = Vec::new();
    let mut cols = Vec::with_capacity(NUM_CHUNKS);
    for raw in [&ts_p, &et_p, &nm_p, &th_p, &pa_p, &ms_p, &tg_p] {
        let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(raw)?;
        let cbytes = enc.finish()?;
        cols.push(ColChunk {
            len: cbytes.len() as u64,
            raw_len: raw.len() as u64,
            crc: fnv32(&cbytes),
        });
        compressed.extend_from_slice(&cbytes);
    }
    Ok(BlockChunk { proc, rows: nrows as u64, span, compressed, cols })
}

/// Decompress + parse one **version-1** monolithic block chunk back
/// into a canonical-schema trace — the CPU half of a legacy archive
/// shard read, safe on any worker. v1 blocks can't be projected; the
/// planner falls back to full decodes for them.
pub(crate) fn decode_block(
    compressed: &[u8],
    crc: u32,
    proc: i64,
    meta: TraceMeta,
) -> Result<Trace> {
    if fnv32(compressed) != crc {
        bail!("archive block for process {proc} failed its checksum (corrupt blocks.bin)");
    }
    let mut payload = Vec::new();
    ZlibDecoder::new(compressed)
        .read_to_end(&mut payload)
        .with_context(|| format!("inflating archive block for process {proc}"))?;
    let buf = &payload[..];
    let mut pos = 0usize;
    let nrows = get_uvarint(buf, &mut pos)? as usize;
    if nrows > payload.len() {
        bail!("archive block declares an implausible row count {nrows}");
    }
    let nnames = get_uvarint(buf, &mut pos)? as usize;
    if nnames > payload.len() {
        bail!("archive block declares an implausible name count {nnames}");
    }
    let mut names = Interner::new();
    for _ in 0..nnames {
        let len = get_uvarint(buf, &mut pos)? as usize;
        let end = pos.checked_add(len).context("archive block name length overflow")?;
        if end > buf.len() {
            bail!("archive block truncated in its name table");
        }
        names.intern(std::str::from_utf8(&buf[pos..end])?);
        pos = end;
    }
    let mut ts = Vec::with_capacity(nrows);
    let mut prev = 0i64;
    for _ in 0..nrows {
        prev = prev.wrapping_add(unzigzag(get_uvarint(buf, &mut pos)?));
        ts.push(prev);
    }
    // event-type codes in the chunk coincide with a fresh
    // Enter/Leave/Instant dictionary's codes (0/1/2)
    let mut edict = Interner::new();
    for s in [ENTER, LEAVE, INSTANT] {
        edict.intern(s);
    }
    let mut et = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let b = *buf.get(pos).context("archive block truncated in event types")?;
        pos += 1;
        if b > ET_INSTANT {
            bail!("archive block: bad event-type byte {b}");
        }
        et.push(b as u32);
    }
    let mut nm = Vec::with_capacity(nrows);
    for _ in 0..nrows {
        let code = get_uvarint(buf, &mut pos)?;
        if code >= nnames as u64 {
            bail!("archive block: name ref {code} out of range");
        }
        nm.push(code as u32);
    }
    let mut i64_col = |pos: &mut usize| -> Result<Vec<i64>> {
        let mut v = Vec::with_capacity(nrows);
        for _ in 0..nrows {
            v.push(unzigzag(get_uvarint(buf, pos)?));
        }
        Ok(v)
    };
    let th = i64_col(&mut pos)?;
    let pa = i64_col(&mut pos)?;
    let ms = i64_col(&mut pos)?;
    let tg = i64_col(&mut pos)?;
    if pos != buf.len() {
        bail!("archive block has trailing bytes");
    }
    let mut table = Table::new();
    table.push(COL_TS, Column::I64(ts))?;
    table.push(COL_TYPE, Column::Str { codes: et, dict: Arc::new(edict) })?;
    table.push(COL_NAME, Column::Str { codes: nm, dict: Arc::new(names) })?;
    table.push(COL_PROC, Column::I64(vec![proc; nrows]))?;
    table.push(COL_THREAD, Column::I64(th))?;
    table.push(COL_PARTNER, Column::I64(pa))?;
    table.push(COL_MSG_SIZE, Column::I64(ms))?;
    table.push(COL_TAG, Column::I64(tg))?;
    Ok(Trace::new(table, meta))
}

/// Decompress + parse a **version-2** block from `region` — the bytes
/// of its chunks read contiguously from the first chunk through the
/// last one `need` names (trailing unneeded chunks may be absent).
/// Skipped columns never touch their bytes and materialize as schema
/// defaults: names as one empty-string code, event types as `Instant`
/// (stack-neutral), i64 columns as `NULL_I64` — no routed analysis that
/// skips a column ever reads it, and the parity suite holds that line.
/// A `window` applies [`crate::exec::ops::window_rows`] in-decode, so a
/// windowed archive shard is born filtered.
pub(crate) fn decode_block_v2(
    region: &[u8],
    cols: &[ColChunk],
    nrows: usize,
    proc: i64,
    meta: TraceMeta,
    need: [bool; NUM_CHUNKS],
    window: Option<(i64, i64)>,
) -> Result<Trace> {
    let mut raw: [Option<Vec<u8>>; NUM_CHUNKS] = Default::default();
    let mut off = 0usize;
    for (k, ch) in cols.iter().enumerate() {
        let len = ch.len as usize;
        if need[k] {
            let end = off.checked_add(len).context("archive chunk length overflow")?;
            if end > region.len() {
                bail!("archive block for process {proc} truncated in column chunk {k}");
            }
            let bytes = &region[off..end];
            if fnv32(bytes) != ch.crc {
                bail!(
                    "archive block for process {proc} failed its checksum in column chunk {k} (corrupt blocks.bin)"
                );
            }
            let mut out = Vec::with_capacity(ch.raw_len as usize);
            ZlibDecoder::new(bytes)
                .read_to_end(&mut out)
                .with_context(|| format!("inflating column chunk {k} for process {proc}"))?;
            if out.len() as u64 != ch.raw_len {
                bail!(
                    "archive column chunk {k} for process {proc} inflated to {} bytes, index says {}",
                    out.len(),
                    ch.raw_len
                );
            }
            raw[k] = Some(out);
        }
        off = off.saturating_add(len);
    }

    let ts = match &raw[0] {
        Some(buf) => {
            let mut v = Vec::with_capacity(nrows);
            let mut pos = 0usize;
            let mut prev = 0i64;
            for _ in 0..nrows {
                prev = prev.wrapping_add(unzigzag(get_uvarint(buf, &mut pos)?));
                v.push(prev);
            }
            if pos != buf.len() {
                bail!("archive timestamp chunk has trailing bytes");
            }
            v
        }
        // every AccessPlan forces TS into its mask; zeros only if
        // called with a hand-rolled mask that dropped it
        None => vec![0i64; nrows],
    };

    // event-type codes coincide with a fresh Enter/Leave/Instant
    // dictionary's codes (0/1/2)
    let mut edict = Interner::new();
    for s in [ENTER, LEAVE, INSTANT] {
        edict.intern(s);
    }
    let et = match &raw[CHUNK_ET] {
        Some(buf) => {
            if buf.len() != nrows {
                bail!("archive event-type chunk has {} bytes for {nrows} rows", buf.len());
            }
            let mut v = Vec::with_capacity(nrows);
            for &b in buf.iter() {
                if b > ET_INSTANT {
                    bail!("archive block: bad event-type byte {b}");
                }
                v.push(b as u32);
            }
            v
        }
        None => vec![ET_INSTANT as u32; nrows],
    };

    let (nm, names) = match &raw[2] {
        Some(buf) => {
            let mut pos = 0usize;
            let nnames = get_uvarint(buf, &mut pos)? as usize;
            if nnames > buf.len() {
                bail!("archive block declares an implausible name count {nnames}");
            }
            let mut names = Interner::new();
            for _ in 0..nnames {
                let len = get_uvarint(buf, &mut pos)? as usize;
                let end = pos.checked_add(len).context("archive block name length overflow")?;
                if end > buf.len() {
                    bail!("archive block truncated in its name table");
                }
                names.intern(std::str::from_utf8(&buf[pos..end])?);
                pos = end;
            }
            let mut v = Vec::with_capacity(nrows);
            for _ in 0..nrows {
                let code = get_uvarint(buf, &mut pos)?;
                if code >= nnames as u64 {
                    bail!("archive block: name ref {code} out of range");
                }
                v.push(code as u32);
            }
            if pos != buf.len() {
                bail!("archive name chunk has trailing bytes");
            }
            (v, names)
        }
        None => {
            let mut names = Interner::new();
            names.intern("");
            (vec![0u32; nrows], names)
        }
    };

    let i64_chunk = |k: usize| -> Result<Vec<i64>> {
        match &raw[k] {
            Some(buf) => {
                let mut v = Vec::with_capacity(nrows);
                let mut pos = 0usize;
                for _ in 0..nrows {
                    v.push(unzigzag(get_uvarint(buf, &mut pos)?));
                }
                if pos != buf.len() {
                    bail!("archive column chunk {k} has trailing bytes");
                }
                Ok(v)
            }
            None => Ok(vec![NULL_I64; nrows]),
        }
    };
    let th = i64_chunk(3)?;
    let pa = i64_chunk(4)?;
    let ms = i64_chunk(5)?;
    let tg = i64_chunk(6)?;

    let mut table = Table::new();
    table.push(COL_TS, Column::I64(ts))?;
    table.push(COL_TYPE, Column::Str { codes: et, dict: Arc::new(edict) })?;
    table.push(COL_NAME, Column::Str { codes: nm, dict: Arc::new(names) })?;
    table.push(COL_PROC, Column::I64(vec![proc; nrows]))?;
    table.push(COL_THREAD, Column::I64(th))?;
    table.push(COL_PARTNER, Column::I64(pa))?;
    table.push(COL_MSG_SIZE, Column::I64(ms))?;
    table.push(COL_TAG, Column::I64(tg))?;
    let t = Trace::new(table, meta);
    match window {
        Some((lo, hi)) => crate::exec::ops::window_rows(&t, lo, hi),
        None => Ok(t),
    }
}

// -- index ------------------------------------------------------------------

/// One block's row in the `index.bin` block table.
#[derive(Debug, Clone)]
pub(crate) struct IndexEntry {
    pub(crate) proc: i64,
    /// Byte offset of the block's compressed bytes within `blocks.bin`.
    pub(crate) offset: u64,
    /// Total compressed length in bytes (v2: the sum of chunk lengths).
    pub(crate) len: u64,
    /// v1 only: FNV-1a of the whole compressed block (v2 entries carry
    /// per-chunk checksums in `cols` instead and store 0 here).
    pub(crate) crc: u32,
    /// Rows the block decodes into.
    pub(crate) rows: u64,
    /// (min, max) timestamp of the block's rows; None when empty.
    pub(crate) span: Option<(i64, i64)>,
    /// v2: the seven per-column chunk frames in file order. Empty for a
    /// v1 entry — the tell that the block needs the legacy full decode.
    pub(crate) cols: Vec<ColChunk>,
}

/// The parsed `index.bin`: everything an archive reopen knows before
/// any shard decodes.
pub(crate) struct ArchiveIndex {
    pub(crate) version: u64,
    pub(crate) meta: TraceMeta,
    pub(crate) entries: Vec<IndexEntry>,
    pub(crate) census: Option<TraceCensus>,
    pub(crate) census_corrupt: bool,
}

/// Write `index.bin`: magic, version, verbatim source meta, the block
/// table, then the length-prefixed FNV-checksummed census section.
pub(crate) fn write_index(
    dir: &Path,
    meta: &TraceMeta,
    entries: &[IndexEntry],
    census: Option<&TraceCensus>,
) -> Result<()> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    put_uvarint(&mut buf, ARCHIVE_VERSION);
    for s in [&meta.format, &meta.source, &meta.app] {
        put_uvarint(&mut buf, s.len() as u64);
        buf.extend_from_slice(s.as_bytes());
    }
    put_uvarint(&mut buf, entries.len() as u64);
    for e in entries {
        if e.cols.len() != NUM_CHUNKS {
            bail!(
                "archive index entry without a column chunk table — v1 entries cannot be rewritten as version {ARCHIVE_VERSION}"
            );
        }
        put_uvarint(&mut buf, zigzag(e.proc));
        put_uvarint(&mut buf, e.offset);
        put_uvarint(&mut buf, e.len);
        put_uvarint(&mut buf, e.rows);
        put_span(&mut buf, e.span);
        put_uvarint(&mut buf, e.cols.len() as u64);
        for ch in &e.cols {
            put_uvarint(&mut buf, ch.len);
            put_uvarint(&mut buf, ch.raw_len);
            buf.extend_from_slice(&ch.crc.to_le_bytes());
        }
    }
    match census {
        Some(c) => {
            let payload = census_payload(c);
            buf.push(CENSUS_MARKER);
            put_uvarint(&mut buf, (payload.len() + 4) as u64);
            buf.extend_from_slice(&payload);
            buf.extend_from_slice(&fnv32(&payload).to_le_bytes());
        }
        None => buf.push(CENSUS_ABSENT),
    }
    let p = dir.join(INDEX_FILE);
    std::fs::write(&p, buf).with_context(|| format!("writing {}", p.display()))
}

fn census_payload(c: &TraceCensus) -> Vec<u8> {
    let mut payload = Vec::new();
    put_uvarint(&mut payload, CENSUS_VERSION);
    put_uvarint(&mut payload, c.blocks.len() as u64);
    for b in &c.blocks {
        put_uvarint(&mut payload, b.rows);
        put_span(&mut payload, b.span);
    }
    match &c.funcs {
        Some(f) => {
            payload.push(1);
            put_uvarint(&mut payload, f.names.len() as u64);
            for (name, &ns) in f.names.iter().zip(&f.exc_ns) {
                put_uvarint(&mut payload, name.len() as u64);
                payload.extend_from_slice(name.as_bytes());
                put_uvarint(&mut payload, zigzag(ns));
            }
        }
        None => payload.push(0),
    }
    match &c.channels {
        Some(chans) => {
            payload.push(1);
            put_uvarint(&mut payload, chans.len() as u64);
            for ch in chans {
                put_uvarint(&mut payload, zigzag(ch.src));
                put_uvarint(&mut payload, zigzag(ch.dst));
                put_uvarint(&mut payload, zigzag(ch.tag));
                put_uvarint(&mut payload, ch.sends);
                put_uvarint(&mut payload, ch.recvs);
            }
        }
        None => payload.push(0),
    }
    match &c.msgs {
        Some(m) => {
            payload.push(1);
            payload.push(m.saw_send as u8);
            put_uvarint(&mut payload, zigzag(m.max_send));
            put_uvarint(&mut payload, zigzag(m.max_recv));
        }
        None => payload.push(0),
    }
    match &c.block_detail {
        Some(detail) => {
            payload.push(1);
            put_uvarint(&mut payload, detail.len() as u64);
            for d in detail {
                put_uvarint(&mut payload, d.funcs.len() as u64);
                for &(slot, ns) in &d.funcs {
                    put_uvarint(&mut payload, slot as u64);
                    put_uvarint(&mut payload, zigzag(ns));
                }
                put_uvarint(&mut payload, d.channels.len() as u64);
                for &(slot, sends, recvs) in &d.channels {
                    put_uvarint(&mut payload, slot as u64);
                    put_uvarint(&mut payload, sends);
                    put_uvarint(&mut payload, recvs);
                }
            }
        }
        None => payload.push(0),
    }
    payload
}

/// Parse `index.bin`. The pre-census part (magic, version, meta, block
/// table) is strict — damage there is an open error. The census section
/// is lenient exactly like the otf2 trailer: any anomaly degrades to
/// census-absent + `census_corrupt`, never an error.
pub(crate) fn read_index(dir: &Path) -> Result<ArchiveIndex> {
    let p = dir.join(INDEX_FILE);
    let buf =
        std::fs::read(&p).with_context(|| format!("reading {}", p.display()))?;
    if buf.len() < 8 || &buf[..8] != MAGIC {
        bail!("bad archive magic in {}", dir.display());
    }
    let mut pos = 8usize;
    let version = get_uvarint(&buf, &mut pos)?;
    if version == 0 || version > ARCHIVE_VERSION {
        return Err(VersionMismatch { found: version, have: ARCHIVE_VERSION }.into());
    }
    fn take<'a>(buf: &'a [u8], pos: &mut usize, len: usize) -> Result<&'a [u8]> {
        let end = pos.checked_add(len).context("index.bin length overflow")?;
        if end > buf.len() {
            bail!("index.bin truncated at byte {pos}");
        }
        let out = &buf[*pos..end];
        *pos = end;
        Ok(out)
    }
    fn field(buf: &[u8], pos: &mut usize) -> Result<String> {
        let len = get_uvarint(buf, pos)? as usize;
        Ok(String::from_utf8(take(buf, pos, len)?.to_vec())?)
    }
    let meta = TraceMeta {
        format: field(&buf, &mut pos)?,
        source: field(&buf, &mut pos)?,
        app: field(&buf, &mut pos)?,
    };
    let nblocks = get_uvarint(&buf, &mut pos)? as usize;
    if nblocks > 100_000_000 {
        bail!("index.bin declares an implausible block count {nblocks}");
    }
    let mut entries = Vec::with_capacity(nblocks);
    for _ in 0..nblocks {
        let proc = unzigzag(get_uvarint(&buf, &mut pos)?);
        let offset = get_uvarint(&buf, &mut pos)?;
        let len = get_uvarint(&buf, &mut pos)?;
        if version == 1 {
            let crc_bytes = take(&buf, &mut pos, 4)?;
            let crc =
                u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
            let rows = get_uvarint(&buf, &mut pos)?;
            let span = get_span(&buf, &mut pos)?;
            entries.push(IndexEntry { proc, offset, len, crc, rows, span, cols: Vec::new() });
        } else {
            let rows = get_uvarint(&buf, &mut pos)?;
            let span = get_span(&buf, &mut pos)?;
            let ncols = get_uvarint(&buf, &mut pos)? as usize;
            if ncols != NUM_CHUNKS {
                bail!(
                    "index.bin block entry has {ncols} column chunks (this build expects {NUM_CHUNKS})"
                );
            }
            let mut cols = Vec::with_capacity(NUM_CHUNKS);
            let mut total = 0u64;
            for _ in 0..NUM_CHUNKS {
                let clen = get_uvarint(&buf, &mut pos)?;
                let raw_len = get_uvarint(&buf, &mut pos)?;
                let crc_bytes = take(&buf, &mut pos, 4)?;
                let crc =
                    u32::from_le_bytes([crc_bytes[0], crc_bytes[1], crc_bytes[2], crc_bytes[3]]);
                total = total.checked_add(clen).context("index.bin chunk length overflow")?;
                cols.push(ColChunk { len: clen, raw_len, crc });
            }
            if total != len {
                bail!("index.bin block entry length {len} disagrees with its chunk sum {total}");
            }
            if cols[CHUNK_ET].raw_len != rows {
                bail!(
                    "index.bin block entry row count {rows} disagrees with its event-type chunk"
                );
            }
            entries.push(IndexEntry { proc, offset, len, crc: 0, rows, span, cols });
        }
    }
    let flag = *buf.get(pos).context("index.bin truncated before the census section")?;
    let (census, census_corrupt) = match flag {
        CENSUS_ABSENT => (None, false),
        _ => parse_census_section(&buf, pos),
    };
    Ok(ArchiveIndex { version, meta, entries, census, census_corrupt })
}

/// Lenient census-section parse (cursor at the marker byte): `(None,
/// true)` for any anomaly, `(None, false)` only for an intact section
/// of an unknown future census version.
fn parse_census_section(buf: &[u8], mut pos: usize) -> (Option<TraceCensus>, bool) {
    let corrupt = (None, true);
    if buf[pos] != CENSUS_MARKER {
        return corrupt;
    }
    pos += 1;
    let Ok(len) = get_uvarint(buf, &mut pos) else { return corrupt };
    let Some(end) = pos.checked_add(len as usize) else { return corrupt };
    if end > buf.len() || len < 4 {
        return corrupt;
    }
    let body_end = end - 4;
    let want = u32::from_le_bytes([
        buf[body_end],
        buf[body_end + 1],
        buf[body_end + 2],
        buf[body_end + 3],
    ]);
    if fnv32(&buf[pos..body_end]) != want {
        return corrupt;
    }
    let body = &buf[..body_end];
    let mut p = pos;
    let parsed = (|| -> Result<Option<TraceCensus>> {
        let version = get_uvarint(body, &mut p)?;
        if version != CENSUS_VERSION {
            return Ok(None); // future version: intact but unknown
        }
        let nblocks = get_uvarint(body, &mut p)? as usize;
        if nblocks > 100_000_000 {
            bail!("implausible census block count");
        }
        let mut blocks = Vec::with_capacity(nblocks);
        for _ in 0..nblocks {
            let rows = get_uvarint(body, &mut p)?;
            let span = get_span(body, &mut p)?;
            blocks.push(BlockCensus { rows, span });
        }
        let funcs = match body.get(p).copied() {
            Some(0) => {
                p += 1;
                None
            }
            Some(1) => {
                p += 1;
                let n = get_uvarint(body, &mut p)? as usize;
                if n > 100_000_000 {
                    bail!("implausible census function count");
                }
                let mut names = Vec::with_capacity(n);
                let mut exc_ns = Vec::with_capacity(n);
                for _ in 0..n {
                    let len = get_uvarint(body, &mut p)? as usize;
                    let end = p.checked_add(len).context("census name overflow")?;
                    if end > body.len() {
                        bail!("census truncated in a function name");
                    }
                    names.push(std::str::from_utf8(&body[p..end])?.to_string());
                    p = end;
                    exc_ns.push(unzigzag(get_uvarint(body, &mut p)?));
                }
                Some(FuncTotals { names, exc_ns })
            }
            _ => bail!("bad census funcs flag"),
        };
        let channels = match body.get(p).copied() {
            Some(0) => {
                p += 1;
                None
            }
            Some(1) => {
                p += 1;
                let n = get_uvarint(body, &mut p)? as usize;
                if n > 100_000_000 {
                    bail!("implausible census channel count");
                }
                let mut chans = Vec::with_capacity(n);
                for _ in 0..n {
                    let src = unzigzag(get_uvarint(body, &mut p)?);
                    let dst = unzigzag(get_uvarint(body, &mut p)?);
                    let tag = unzigzag(get_uvarint(body, &mut p)?);
                    let sends = get_uvarint(body, &mut p)?;
                    let recvs = get_uvarint(body, &mut p)?;
                    chans.push(ChannelCensus { src, dst, tag, sends, recvs });
                }
                Some(chans)
            }
            _ => bail!("bad census channels flag"),
        };
        let msgs = match body.get(p).copied() {
            Some(0) => {
                p += 1;
                None
            }
            Some(1) => {
                p += 1;
                let saw_send = match body.get(p).copied() {
                    Some(0) => false,
                    Some(1) => true,
                    _ => bail!("bad census saw_send flag"),
                };
                p += 1;
                let max_send = unzigzag(get_uvarint(body, &mut p)?);
                let max_recv = unzigzag(get_uvarint(body, &mut p)?);
                Some(MsgCensus { max_send, max_recv, saw_send })
            }
            _ => bail!("bad census msgs flag"),
        };
        let nfuncs = funcs.as_ref().map_or(0, |f| f.names.len());
        let nchans = channels.as_ref().map_or(0, |c| c.len());
        let block_detail = match body.get(p).copied() {
            Some(0) => {
                p += 1;
                None
            }
            Some(1) => {
                p += 1;
                let n = get_uvarint(body, &mut p)? as usize;
                if n != nblocks {
                    bail!("census block detail count disagrees with the block table");
                }
                let mut detail = Vec::with_capacity(n);
                for _ in 0..n {
                    let nf = get_uvarint(body, &mut p)? as usize;
                    if nf > nfuncs {
                        bail!("census block detail lists more functions than the census");
                    }
                    let mut funcs_d = Vec::with_capacity(nf);
                    for _ in 0..nf {
                        let slot = get_uvarint(body, &mut p)?;
                        if slot >= nfuncs as u64 {
                            bail!("census block detail function slot out of range");
                        }
                        funcs_d.push((slot as u32, unzigzag(get_uvarint(body, &mut p)?)));
                    }
                    let nc = get_uvarint(body, &mut p)? as usize;
                    if nc > nchans {
                        bail!("census block detail lists more channels than the census");
                    }
                    let mut chans_d = Vec::with_capacity(nc);
                    for _ in 0..nc {
                        let slot = get_uvarint(body, &mut p)?;
                        if slot >= nchans as u64 {
                            bail!("census block detail channel slot out of range");
                        }
                        let sends = get_uvarint(body, &mut p)?;
                        let recvs = get_uvarint(body, &mut p)?;
                        chans_d.push((slot as u32, sends, recvs));
                    }
                    detail.push(BlockDetail { funcs: funcs_d, channels: chans_d });
                }
                Some(detail)
            }
            _ => bail!("bad census block-detail flag"),
        };
        if p != body_end {
            bail!("census payload has trailing bytes");
        }
        Ok(Some(TraceCensus {
            version,
            blocks,
            funcs,
            channels,
            msgs,
            block_detail,
        }))
    })();
    match parsed {
        Ok(Some(c)) => (Some(c), false),
        Ok(None) => (None, false),
        Err(_) => corrupt,
    }
}

// -- census merging (conversion fold) ---------------------------------------

/// Deterministic shard-order merge of per-shard censuses into the one
/// stream-wide census the archive embeds. First-seen function / channel
/// order across shards in fold order equals the order a sequential
/// census over the whole stream would produce, and integer totals sum
/// exactly — so the merged census is bit-identical to a whole-run
/// pre-scan. Any shard without a census forfeits the merge (an archive
/// census that might disagree with the rows must not exist).
pub(crate) struct CensusMerger {
    forfeited: bool,
    blocks: Vec<BlockCensus>,
    details: Vec<BlockDetail>,
    func_slot: HashMap<String, usize>,
    func_names: Vec<String>,
    func_ns: Vec<i64>,
    chan_slot: HashMap<(i64, i64, i64), usize>,
    chans: Vec<ChannelCensus>,
    msgs: MsgCensus,
}

impl CensusMerger {
    pub(crate) fn new() -> Self {
        CensusMerger {
            forfeited: false,
            blocks: Vec::new(),
            details: Vec::new(),
            func_slot: HashMap::new(),
            func_names: Vec::new(),
            func_ns: Vec::new(),
            chan_slot: HashMap::new(),
            chans: Vec::new(),
            msgs: MsgCensus { max_send: -1, max_recv: -1, saw_send: false },
        }
    }

    /// Fold one shard's census (in shard order).
    pub(crate) fn merge(&mut self, census: Option<TraceCensus>) {
        if self.forfeited {
            return;
        }
        let Some(c) = census else {
            self.forfeited = true;
            return;
        };
        let (Some(funcs), Some(channels), Some(msgs), Some(detail)) =
            (c.funcs, c.channels, c.msgs, c.block_detail)
        else {
            self.forfeited = true;
            return;
        };
        let fmap: Vec<u32> = funcs
            .names
            .iter()
            .zip(&funcs.exc_ns)
            .map(|(name, &ns)| {
                let next = self.func_names.len();
                let slot = *self.func_slot.entry(name.clone()).or_insert(next);
                if slot == next {
                    self.func_names.push(name.clone());
                    self.func_ns.push(0);
                }
                self.func_ns[slot] += ns;
                slot as u32
            })
            .collect();
        let cmap: Vec<u32> = channels
            .iter()
            .map(|ch| {
                let next = self.chans.len();
                let slot = *self.chan_slot.entry((ch.src, ch.dst, ch.tag)).or_insert(next);
                if slot == next {
                    self.chans.push(ChannelCensus {
                        src: ch.src,
                        dst: ch.dst,
                        tag: ch.tag,
                        sends: 0,
                        recvs: 0,
                    });
                }
                self.chans[slot].sends += ch.sends;
                self.chans[slot].recvs += ch.recvs;
                slot as u32
            })
            .collect();
        self.msgs.max_send = self.msgs.max_send.max(msgs.max_send);
        self.msgs.max_recv = self.msgs.max_recv.max(msgs.max_recv);
        self.msgs.saw_send |= msgs.saw_send;
        self.blocks.extend(c.blocks);
        for d in detail {
            let mut funcs_d: Vec<(u32, i64)> = d
                .funcs
                .iter()
                .map(|&(s, ns)| (fmap[s as usize], ns))
                .collect();
            funcs_d.sort_unstable_by_key(|&(s, _)| s);
            let mut chans_d: Vec<(u32, u64, u64)> = d
                .channels
                .iter()
                .map(|&(s, sends, recvs)| (cmap[s as usize], sends, recvs))
                .collect();
            chans_d.sort_unstable_by_key(|&(s, _, _)| s);
            self.details.push(BlockDetail { funcs: funcs_d, channels: chans_d });
        }
    }

    /// The merged stream-wide census, or None when any shard forfeited.
    pub(crate) fn finish(self) -> Option<TraceCensus> {
        if self.forfeited {
            return None;
        }
        Some(TraceCensus {
            version: CENSUS_VERSION,
            blocks: self.blocks,
            funcs: Some(FuncTotals { names: self.func_names, exc_ns: self.func_ns }),
            channels: Some(self.chans),
            msgs: Some(self.msgs),
            block_detail: Some(self.details),
        })
    }
}

// -- reopening: the zero-pre-scan sharded reader ----------------------------

/// The column mask as a per-chunk lookup, index-aligned with the block
/// chunk order (and [`ColumnSet`]'s bit positions).
fn need_of(cols: &ColumnSet) -> [bool; NUM_CHUNKS] {
    [
        cols.has(ColumnSet::TS),
        cols.has(ColumnSet::TYPE),
        cols.has(ColumnSet::NAME),
        cols.has(ColumnSet::THREAD),
        cols.has(ColumnSet::PARTNER),
        cols.has(ColumnSet::MSG_SIZE),
        cols.has(ColumnSet::TAG),
    ]
}

/// Archive reader: `open` parses `index.bin` only; every shard read is
/// one seek + one bounded `read_exact` (the driver's pure-I/O half) and
/// one checksum + inflate + parse (the worker half). Span, shard count
/// and the full census — per-block sub-censuses included — are known
/// before any shard decodes: zero pre-scan, for every source format the
/// archive was converted from.
///
/// [`open_with`](ArchiveBlocks::open_with) additionally plans the read
/// against an [`AccessPlan`]: block pruning by span/sub-census, column
/// projection on v2 blocks, and a small readahead of surviving block
/// byte-ranges (`ARCHIVE_READAHEAD_BLOCKS`).
pub struct ArchiveBlocks {
    file: std::fs::File,
    meta: TraceMeta,
    /// Surviving blocks only, renumbered 0..k in original block order.
    entries: Vec<IndexEntry>,
    census: Option<TraceCensus>,
    census_corrupt: bool,
    next: usize,
    /// Tasks already read off disk, waiting to be handed out.
    ready: VecDeque<ShardTask>,
    /// How many block byte-ranges one refill reads ahead.
    readahead: usize,
    /// Which chunks the plan inflates (all true for a full read).
    need: [bool; NUM_CHUNKS],
    /// Concrete window bounds when the plan is windowed.
    window: Option<(i64, i64)>,
    /// Span folded over *all* blocks, before any pruning.
    full_span: Option<(i64, i64)>,
    prune: PruneStats,
}

impl ArchiveBlocks {
    /// Full scan: every block, every column, no window.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, &AccessPlan::full())
    }

    /// Open the archive and plan the read. Pruning is conservative:
    /// a block is skipped only when its strict index span misses the
    /// window, or when the embedded census *proves* the plan's
    /// predicate can't match inside it (v2 archives with an intact
    /// census only). Everything else decodes — census-absent and
    /// corrupt-census archives degrade to full scans, never to
    /// different results.
    pub fn open_with(dir: &Path, access: &AccessPlan) -> Result<Self> {
        let idx = read_index(dir)?;
        let p = dir.join(BLOCKS_FILE);
        let file = std::fs::File::open(&p)
            .with_context(|| format!("opening {}", p.display()))?;
        let size = file.metadata()?.len();
        for (i, e) in idx.entries.iter().enumerate() {
            let end = e.offset.checked_add(e.len).context("blocks.bin offset overflow")?;
            if end > size {
                bail!(
                    "blocks.bin truncated: block {i} ends at byte {end} but the file has {size}"
                );
            }
        }

        let mut full_span: Option<(i64, i64)> = None;
        for e in &idx.entries {
            if let Some((lo, hi)) = e.span {
                full_span = Some(match full_span {
                    Some((a, z)) => (a.min(lo), z.max(hi)),
                    None => (lo, hi),
                });
            }
        }

        let window =
            access.window.map(|(s, e)| (s.unwrap_or(i64::MIN), e.unwrap_or(i64::MAX)));
        let n = idx.entries.len();
        let mut keep = vec![true; n];
        if let Some((lo, hi)) = window {
            for (i, e) in idx.entries.iter().enumerate() {
                // strict block-table spans are exact, so span-misses
                // are proof: no row of the block lands in the window
                if let Some((blo, bhi)) = e.span {
                    if bhi < lo || blo > hi {
                        keep[i] = false;
                    }
                }
            }
        }
        let mut predicate_pruned = false;
        if matches!(access.predicate, Predicate::ChannelTraffic)
            && window.is_none()
            && idx.version >= 2
            && !idx.census_corrupt
        {
            // v2-only: v1 censuses were written with type-gated endpoint
            // accounting, so only a v2 sub-census proves channel absence
            if let Some(c) = &idx.census {
                if let Some(detail) = &c.block_detail {
                    if detail.len() == n && c.blocks.len() == n {
                        for i in 0..n {
                            if keep[i] && detail[i].channels.is_empty() {
                                keep[i] = false;
                                predicate_pruned = true;
                            }
                        }
                    }
                }
            }
        }

        let mut census = idx.census;
        if predicate_pruned {
            // keep the census aligned with the surviving shards: filter
            // blocks + sub-censuses to survivors in order, leave the
            // global sections (funcs/channels/msgs) untouched
            if let Some(c) = &mut census {
                let mut kb = keep.iter().copied();
                c.blocks.retain(|_| kb.next().unwrap());
                if let Some(d) = &mut c.block_detail {
                    let mut kd = keep.iter().copied();
                    d.retain(|_| kd.next().unwrap());
                }
            }
        }

        let mut prune = PruneStats::default();
        let mut entries = Vec::with_capacity(n);
        for (i, e) in idx.entries.into_iter().enumerate() {
            if keep[i] {
                entries.push(e);
            } else {
                prune.blocks_pruned += 1;
                prune.bytes_skipped += e.len;
            }
        }

        let need = need_of(&access.columns);
        for e in &entries {
            if e.cols.len() == NUM_CHUNKS {
                for (k, ch) in e.cols.iter().enumerate() {
                    if !need[k] {
                        prune.columns_skipped += 1;
                        prune.bytes_skipped += ch.len;
                    }
                }
            }
        }

        let readahead = crate::exec::pool::env_knob(
            "ARCHIVE_READAHEAD_BLOCKS",
            4usize,
            "a positive integer",
            "reading 4 blocks ahead",
            |v| v.trim().parse::<usize>().ok().filter(|&x| x >= 1),
        )
        .max(1);

        Ok(ArchiveBlocks {
            file,
            meta: idx.meta,
            entries,
            census,
            census_corrupt: idx.census_corrupt,
            next: 0,
            ready: VecDeque::new(),
            readahead,
            need,
            window,
            full_span,
            prune,
        })
    }

    /// Read the next up-to-`readahead` surviving block byte-ranges off
    /// disk and queue their decode tasks — the small I/O batch that
    /// lets workers inflate block `i` while block `i+1`'s bytes load.
    fn refill(&mut self) -> Result<()> {
        for _ in 0..self.readahead {
            if self.next >= self.entries.len() {
                return Ok(());
            }
            let index = self.next;
            self.next += 1;
            let e = self.entries[index].clone();
            let read_len = if e.cols.len() == NUM_CHUNKS {
                // trimmed read: chunks are contiguous in mask order, so
                // stop after the last one the plan inflates
                let hi = (0..NUM_CHUNKS).rev().find(|&k| self.need[k]).unwrap_or(0);
                e.cols[..=hi].iter().map(|c| c.len).sum::<u64>()
            } else {
                e.len
            };
            self.file.seek(SeekFrom::Start(e.offset))?;
            let mut buf = vec![0u8; read_len as usize];
            self.file
                .read_exact(&mut buf)
                .with_context(|| format!("reading archive block {index}"))?;
            let meta = self.meta.clone();
            let window = self.window;
            let decode: Box<dyn FnOnce() -> Result<Trace> + Send> = if e.cols.is_empty() {
                // v1 block: monolithic chunk, full decode (+ in-decode
                // window filter when the plan is windowed)
                Box::new(move || {
                    let t = decode_block(&buf, e.crc, e.proc, meta)?;
                    match window {
                        Some((lo, hi)) => crate::exec::ops::window_rows(&t, lo, hi),
                        None => Ok(t),
                    }
                })
            } else {
                let need = self.need;
                Box::new(move || {
                    decode_block_v2(&buf, &e.cols, e.rows as usize, e.proc, meta, need, window)
                })
            };
            self.ready.push_back(ShardTask::new(index, read_len as usize, decode));
        }
        Ok(())
    }
}

impl ShardedReader for ArchiveBlocks {
    fn next_shard(&mut self) -> Result<Option<TraceShard>> {
        self.next_task()?.map(ShardTask::into_shard).transpose()
    }

    fn next_task(&mut self) -> Result<Option<ShardTask>> {
        if self.ready.is_empty() {
            self.refill()?;
        }
        Ok(self.ready.pop_front())
    }

    fn scan_span(&mut self) -> Result<Option<(i64, i64)>> {
        // folded from the strict index block spans pre-prune — works
        // even when the census section is corrupt. A windowed open
        // hides it: the filtered rows' range must be recomputed from
        // what survives the window, exactly like the eager path.
        if self.window.is_some() {
            return Ok(None);
        }
        Ok(self.full_span)
    }

    fn census(&self) -> Option<&TraceCensus> {
        // the census describes unfiltered rows; a windowed open hides
        // it so every analysis takes its census-less path (which the
        // parity suite pins to the eager results)
        if self.window.is_some() {
            return None;
        }
        self.census.as_ref()
    }

    fn census_corrupt(&self) -> bool {
        self.census_corrupt
    }

    fn prune_stats(&self) -> PruneStats {
        self.prune
    }

    fn shard_count_hint(&self) -> Option<usize> {
        Some(self.entries.len())
    }

    fn is_streaming(&self) -> bool {
        true
    }
}

// -- archive facts (the `pipit convert` summary) ----------------------------

/// What an archive directory holds, lifted from `index.bin` alone —
/// the post-conversion summary `pipit convert` prints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArchiveSummary {
    /// Process-aligned blocks in the block table.
    pub blocks: usize,
    /// Rows across all blocks.
    pub rows: u64,
    /// Bytes on disk: `blocks.bin` (sum of block lengths) + `index.bin`.
    pub on_disk_bytes: u64,
    /// Bytes the blocks decode into (sum of chunk raw lengths); 0 for a
    /// version-1 archive, whose index doesn't record raw lengths.
    pub decoded_bytes: u64,
}

/// Summarize an archive directory from its index — no block decodes.
pub fn describe(dir: &Path) -> Result<ArchiveSummary> {
    let idx = read_index(dir)?;
    let mut s = ArchiveSummary {
        blocks: idx.entries.len(),
        rows: 0,
        on_disk_bytes: std::fs::metadata(dir.join(INDEX_FILE))?.len(),
        decoded_bytes: 0,
    };
    for e in &idx.entries {
        s.rows += e.rows;
        s.on_disk_bytes += e.len;
        for ch in &e.cols {
            s.decoded_bytes += ch.raw_len;
        }
    }
    Ok(s)
}

// -- eager read -------------------------------------------------------------

/// Read a whole archive eagerly (the `read_auto` path): every block
/// decoded and concatenated in block order with one global name
/// dictionary, reproducing the canonical row order of the source trace.
pub fn read(dir: &Path) -> Result<Trace> {
    let mut r = ArchiveBlocks::open(dir)?;
    let meta = r.meta.clone();
    let mut ts = Vec::new();
    let mut et = Vec::new();
    let mut nm = Vec::new();
    let mut pr = Vec::new();
    let mut th = Vec::new();
    let mut pa = Vec::new();
    let mut ms = Vec::new();
    let mut tg = Vec::new();
    let mut names = Interner::new();
    let mut edict = Interner::new();
    for s in [ENTER, LEAVE, INSTANT] {
        edict.intern(s);
    }
    while let Some(sh) = r.next_shard()? {
        let t = sh.trace;
        let (set, sed) = t.events.strs(COL_TYPE)?;
        let (snm, snd) = t.events.strs(COL_NAME)?;
        for i in 0..t.len() {
            et.push(edict.intern(sed.resolve(set[i]).unwrap_or(INSTANT)));
            nm.push(names.intern(snd.resolve(snm[i]).unwrap_or("")));
        }
        ts.extend_from_slice(t.events.i64s(COL_TS)?);
        pr.extend_from_slice(t.events.i64s(COL_PROC)?);
        th.extend_from_slice(t.events.i64s(COL_THREAD)?);
        pa.extend_from_slice(t.events.i64s(COL_PARTNER)?);
        ms.extend_from_slice(t.events.i64s(COL_MSG_SIZE)?);
        tg.extend_from_slice(t.events.i64s(COL_TAG)?);
    }
    let mut table = Table::new();
    table.push(COL_TS, Column::I64(ts))?;
    table.push(COL_TYPE, Column::Str { codes: et, dict: Arc::new(edict) })?;
    table.push(COL_NAME, Column::Str { codes: nm, dict: Arc::new(names) })?;
    table.push(COL_PROC, Column::I64(pr))?;
    table.push(COL_THREAD, Column::I64(th))?;
    table.push(COL_PARTNER, Column::I64(pa))?;
    table.push(COL_MSG_SIZE, Column::I64(ms))?;
    table.push(COL_TAG, Column::I64(tg))?;
    Ok(Trace::new(table, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::df::NULL_I64;
    use crate::exec::stream::write_archive;
    use crate::readers::streaming::SplitReader;
    use std::path::PathBuf;

    fn sample() -> Trace {
        let mut b = TraceBuilder::new();
        b.set_meta(TraceMeta {
            format: "csv".into(),
            source: "orig.csv".into(),
            app: "toy".into(),
        });
        for r in 0..3i64 {
            let mut t = 0;
            b.enter(r, 0, t, "main");
            t += 10;
            b.enter(r, 0, t, "compute");
            t += 50;
            b.leave(r, 0, t, "compute");
            t += 5;
            b.enter(r, 0, t, "MPI_Send");
            b.send(r, 0, t + 1, (r + 1) % 3, 4096, 7);
            t += 10;
            b.leave(r, 0, t, "MPI_Send");
            b.recv(r, 0, t + 2, (r + 2) % 3, 4096, 7);
            b.instant(r, 0, t + 3, "marker");
            b.leave(r, 0, t + 20, "main");
        }
        b.finish()
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("pipit_archive_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn convert(t: &Trace, dir: &Path) {
        let mut r = SplitReader::new(t.clone()).unwrap();
        write_archive(&mut r, dir, 1).unwrap();
    }

    fn dump(t: &Trace) -> String {
        let ts = t.events.i64s(COL_TS).unwrap();
        let (et, edict) = t.events.strs(COL_TYPE).unwrap();
        let (nm, ndict) = t.events.strs(COL_NAME).unwrap();
        let pr = t.events.i64s(COL_PROC).unwrap();
        let th = t.events.i64s(COL_THREAD).unwrap();
        let pa = t.events.i64s(COL_PARTNER).unwrap();
        let ms = t.events.i64s(COL_MSG_SIZE).unwrap();
        let tg = t.events.i64s(COL_TAG).unwrap();
        let mut out = String::new();
        for i in 0..t.len() {
            out.push_str(&format!(
                "{}|{}|{}|{}|{}|{}|{}|{}\n",
                ts[i],
                edict.resolve(et[i]).unwrap_or("?"),
                ndict.resolve(nm[i]).unwrap_or("?"),
                pr[i],
                th[i],
                pa[i],
                ms[i],
                tg[i],
            ));
        }
        out
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN, NULL_I64] {
            assert_eq!(unzigzag(zigzag(v)), v, "{v}");
        }
    }

    #[test]
    fn roundtrip_preserves_rows_thread_nulls_and_meta() {
        let t = sample();
        let dir = tmp("rt");
        convert(&t, &dir);
        let t2 = read(&dir).unwrap();
        // every column bit-identical, meta stored verbatim
        assert_eq!(dump(&t2), dump(&t));
        assert_eq!(t2.meta.format, "csv");
        assert_eq!(t2.meta.source, "orig.csv");
        assert_eq!(t2.meta.app, "toy");
    }

    #[test]
    fn reopen_knows_everything_before_any_decode() {
        let t = sample();
        let dir = tmp("census");
        convert(&t, &dir);
        let mut r = ArchiveBlocks::open(&dir).unwrap();
        assert!(r.is_streaming());
        assert_eq!(r.shard_count_hint(), Some(3));
        assert_eq!(r.scan_span().unwrap(), Some(t.time_range().unwrap()));
        assert!(!r.census_corrupt());
        let c = r.census().expect("archive census");
        assert_eq!(c.total_rows(), t.len() as u64);
        assert_eq!(c.blocks.len(), 3);
        let detail = c.block_detail.as_ref().expect("per-block sub-censuses");
        assert_eq!(detail.len(), 3);
        // the block x function matrix columns sum to the global census
        let funcs = c.funcs.as_ref().unwrap();
        let mut sums = vec![0i64; funcs.names.len()];
        for d in detail {
            for &(slot, ns) in &d.funcs {
                sums[slot as usize] += ns;
            }
        }
        assert_eq!(sums, funcs.exc_ns);
        // streamed rows match the source bit for bit
        let mut out = String::new();
        while let Some(sh) = r.next_shard().unwrap() {
            out.push_str(&dump(&sh.trace));
        }
        assert_eq!(out, dump(&t));
    }

    #[test]
    fn rejects_bad_magic_and_bad_version() {
        let dir = tmp("badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(INDEX_FILE), b"NOTPIPAR____").unwrap();
        std::fs::write(dir.join(BLOCKS_FILE), b"").unwrap();
        let err = ArchiveBlocks::open(&dir).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");

        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        put_uvarint(&mut buf, ARCHIVE_VERSION + 9);
        std::fs::write(dir.join(INDEX_FILE), buf).unwrap();
        let err = ArchiveBlocks::open(&dir).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn truncated_index_is_a_deterministic_open_error() {
        let t = sample();
        let dir = tmp("truncidx");
        convert(&t, &dir);
        let full = std::fs::read(dir.join(INDEX_FILE)).unwrap();
        std::fs::write(dir.join(INDEX_FILE), &full[..12]).unwrap();
        let a = ArchiveBlocks::open(&dir).unwrap_err().to_string();
        let b = ArchiveBlocks::open(&dir).unwrap_err().to_string();
        assert_eq!(a, b, "open error must be deterministic");
    }

    #[test]
    fn truncated_blocks_file_is_a_deterministic_open_error() {
        let t = sample();
        let dir = tmp("truncblk");
        convert(&t, &dir);
        let full = std::fs::read(dir.join(BLOCKS_FILE)).unwrap();
        std::fs::write(dir.join(BLOCKS_FILE), &full[..full.len() / 2]).unwrap();
        let err = ArchiveBlocks::open(&dir).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn bit_flipped_chunk_fails_its_shard_deterministically() {
        let t = sample();
        let dir = tmp("bitflip");
        convert(&t, &dir);
        let mut blocks = std::fs::read(dir.join(BLOCKS_FILE)).unwrap();
        let mid = blocks.len() / 2;
        blocks[mid] ^= 0x40;
        std::fs::write(dir.join(BLOCKS_FILE), &blocks).unwrap();
        let drain = || -> String {
            let mut r = ArchiveBlocks::open(&dir).unwrap();
            loop {
                match r.next_shard() {
                    Ok(Some(_)) => continue,
                    Ok(None) => panic!("bit flip went undetected"),
                    Err(e) => return e.to_string(),
                }
            }
        };
        let a = drain();
        assert!(a.contains("checksum"), "{a}");
        assert_eq!(a, drain(), "decode error must be deterministic");
    }

    #[test]
    fn corrupt_census_degrades_to_absent_but_still_streams() {
        let t = sample();
        let dir = tmp("badcensus");
        convert(&t, &dir);
        // flip the census section's trailing checksum byte: the strict
        // block table is untouched, the lenient census parse degrades
        let mut idx = std::fs::read(dir.join(INDEX_FILE)).unwrap();
        let last = idx.len() - 1;
        idx[last] ^= 0xFF;
        std::fs::write(dir.join(INDEX_FILE), &idx).unwrap();
        let mut r = ArchiveBlocks::open(&dir).unwrap();
        assert!(r.census().is_none());
        assert!(r.census_corrupt());
        // rows are unaffected
        let mut out = String::new();
        while let Some(sh) = r.next_shard().unwrap() {
            out.push_str(&dump(&sh.trace));
        }
        assert_eq!(out, dump(&t));
    }

    #[test]
    fn archive_without_census_reopens_clean() {
        let t = sample();
        let dir = tmp("nocensus");
        convert(&t, &dir);
        // rewrite the index with the census omitted entirely
        let idx = read_index(&dir).unwrap();
        write_index(&dir, &idx.meta, &idx.entries, None).unwrap();
        let r = ArchiveBlocks::open(&dir).unwrap();
        assert!(r.census().is_none());
        assert!(!r.census_corrupt(), "absent census is not corruption");
    }

    #[test]
    fn version_bump_is_a_typed_open_error() {
        let t = sample();
        let dir = tmp("verbump");
        convert(&t, &dir);
        // hand-bump the version varint right after the 8-byte magic
        let mut idx = std::fs::read(dir.join(INDEX_FILE)).unwrap();
        assert_eq!(idx[8] as u64, ARCHIVE_VERSION);
        idx[8] = ARCHIVE_VERSION as u8 + 1;
        std::fs::write(dir.join(INDEX_FILE), idx).unwrap();
        let err = ArchiveBlocks::open(&dir).unwrap_err();
        let vm = err.downcast_ref::<VersionMismatch>().expect("typed version error");
        assert_eq!(*vm, VersionMismatch { found: ARCHIVE_VERSION + 1, have: ARCHIVE_VERSION });
        assert_eq!(
            err.to_string(),
            format!(
                "archive version {} unsupported (have {ARCHIVE_VERSION})",
                ARCHIVE_VERSION + 1
            )
        );
    }

    /// Three processes with disjoint time spans, so a window can
    /// provably miss whole blocks.
    fn staggered() -> Trace {
        let mut b = TraceBuilder::new();
        for r in 0..3i64 {
            let t0 = r * 1000;
            b.enter(r, 0, t0, "main");
            b.enter(r, 0, t0 + 10, "compute");
            b.leave(r, 0, t0 + 60, "compute");
            b.instant(r, 0, t0 + 70, "marker");
            b.leave(r, 0, t0 + 100, "main");
        }
        b.finish()
    }

    #[test]
    fn windowed_open_prunes_blocks_and_filters_in_decode() {
        let t = staggered();
        let dir = tmp("window");
        convert(&t, &dir);
        let plan = AccessPlan::full().windowed(Some(900), Some(1200));
        let mut r = ArchiveBlocks::open_with(&dir, &plan).unwrap();
        // blocks 0 and 2 provably miss the window; only block 1 survives
        assert_eq!(r.shard_count_hint(), Some(1));
        let stats = r.prune_stats();
        assert_eq!(stats.blocks_pruned, 2);
        assert!(stats.bytes_skipped > 0);
        // census + span describe the unfiltered stream: both hidden
        assert!(r.census().is_none());
        assert!(!r.census_corrupt());
        assert_eq!(r.scan_span().unwrap(), None);
        // the surviving shard decodes pre-filtered, bit-identical to
        // windowing the eager trace
        let mut out = String::new();
        while let Some(sh) = r.next_shard().unwrap() {
            out.push_str(&dump(&sh.trace));
        }
        let eager = crate::exec::ops::window_rows(&t, 900, 1200).unwrap();
        assert!(!out.is_empty());
        assert_eq!(out, dump(&eager));
    }

    #[test]
    fn projection_inflates_only_named_columns() {
        let t = sample();
        let dir = tmp("proj");
        convert(&t, &dir);
        let plan = AccessPlan::for_op("flat_profile"); // ts + type + name
        let mut r = ArchiveBlocks::open_with(&dir, &plan).unwrap();
        let stats = r.prune_stats();
        assert_eq!(stats.blocks_pruned, 0);
        assert_eq!(stats.columns_skipped, 3 * 4, "thread/partner/size/tag × 3 blocks");
        assert!(stats.bytes_skipped > 0);
        // projection changes which bytes inflate, not which rows exist:
        // the census stays visible and aligned
        assert!(r.census().is_some());
        let src_ts = t.events.i64s(COL_TS).unwrap();
        let (src_nm, src_nd) = t.events.strs(COL_NAME).unwrap();
        let (src_et, src_ed) = t.events.strs(COL_TYPE).unwrap();
        let mut row = 0usize;
        while let Some(sh) = r.next_shard().unwrap() {
            let s = sh.trace;
            let ts = s.events.i64s(COL_TS).unwrap();
            let (nm, nd) = s.events.strs(COL_NAME).unwrap();
            let (et, ed) = s.events.strs(COL_TYPE).unwrap();
            let th = s.events.i64s(COL_THREAD).unwrap();
            let pa = s.events.i64s(COL_PARTNER).unwrap();
            let ms = s.events.i64s(COL_MSG_SIZE).unwrap();
            let tg = s.events.i64s(COL_TAG).unwrap();
            for i in 0..s.len() {
                assert_eq!(ts[i], src_ts[row]);
                assert_eq!(nd.resolve(nm[i]), src_nd.resolve(src_nm[row]));
                assert_eq!(ed.resolve(et[i]), src_ed.resolve(src_et[row]));
                assert_eq!(th[i], NULL_I64);
                assert_eq!(pa[i], NULL_I64);
                assert_eq!(ms[i], NULL_I64);
                assert_eq!(tg[i], NULL_I64);
                row += 1;
            }
        }
        assert_eq!(row, t.len());
    }

    /// Two processes exchanging messages plus one pure-compute process
    /// whose channel sub-census is empty.
    fn mixed_comm() -> Trace {
        let mut b = TraceBuilder::new();
        for r in 0..2i64 {
            b.enter(r, 0, 0, "main");
            b.send(r, 0, 10, 1 - r, 256, 1);
            b.recv(r, 0, 20, 1 - r, 256, 1);
            b.leave(r, 0, 100, "main");
        }
        b.enter(2, 0, 0, "main");
        b.enter(2, 0, 10, "compute");
        b.leave(2, 0, 90, "compute");
        b.leave(2, 0, 100, "main");
        b.finish()
    }

    #[test]
    fn channel_predicate_prunes_endpoint_free_blocks() {
        let t = mixed_comm();
        let dir = tmp("chanpred");
        convert(&t, &dir);
        let plan = AccessPlan::for_op("message_histogram");
        assert!(matches!(plan.predicate, Predicate::ChannelTraffic));
        let mut r = ArchiveBlocks::open_with(&dir, &plan).unwrap();
        // process 2 never touches a channel: its sub-census proves it
        assert_eq!(r.prune_stats().blocks_pruned, 1);
        assert_eq!(r.shard_count_hint(), Some(2));
        // the filtered census stays aligned with the surviving shards;
        // global sections are untouched
        let c = r.census().expect("census survives predicate pruning");
        assert_eq!(c.blocks.len(), 2);
        assert_eq!(c.block_detail.as_ref().unwrap().len(), 2);
        assert!(!c.channels.as_ref().unwrap().is_empty());
        let mut procs = Vec::new();
        while let Some(sh) = r.next_shard().unwrap() {
            procs.push(sh.trace.events.i64s(COL_PROC).unwrap()[0]);
        }
        assert_eq!(procs, vec![0, 1]);
    }

    #[test]
    fn predicate_needs_census_proof_to_prune() {
        let t = mixed_comm();
        let dir = tmp("chanabs");
        convert(&t, &dir);
        // strip the census: without proof, every block must decode
        let idx = read_index(&dir).unwrap();
        write_index(&dir, &idx.meta, &idx.entries, None).unwrap();
        let r =
            ArchiveBlocks::open_with(&dir, &AccessPlan::for_op("message_histogram")).unwrap();
        assert_eq!(r.prune_stats().blocks_pruned, 0);
        assert_eq!(r.shard_count_hint(), Some(3));
    }
}
