//! The uniform trace data model.
//!
//! Every reader parses its format into the same events [`Table`] with the
//! canonical schema below (paper §III.A–B), so all analysis operations are
//! single-source across formats:
//!
//! | column            | type | meaning                                        |
//! |-------------------|------|------------------------------------------------|
//! | `Timestamp (ns)`  | i64  | event time                                     |
//! | `Event Type`      | str  | `Enter`, `Leave`, or `Instant`                 |
//! | `Name`            | str  | function / region / instant-event name         |
//! | `Process`         | i64  | MPI rank (or pid)                              |
//! | `Thread`          | i64  | thread id within the process (0 if untraced)   |
//! | `Partner`         | i64  | message peer rank (null unless msg event)      |
//! | `Msg Size`        | i64  | message bytes (null unless msg event)          |
//! | `Tag`             | i64  | message tag (null unless msg event)            |
//!
//! Point-to-point communication appears as `Instant` events named
//! [`SEND_EVENT`] / [`RECV_EVENT`] timestamped inside the surrounding
//! `MPI_Send` / `MPI_Recv` (etc.) function call, mirroring how OTF2
//! separates region enter/leave records from MPI message records.
//!
//! Events are canonically ordered by (Process, Thread, Timestamp); readers
//! guarantee this (it is what per-rank stream formats produce naturally).

pub mod builder;

pub use builder::TraceBuilder;

use crate::df::{Expr, Table};
use anyhow::Result;
use std::path::Path;

// -- canonical column names ---------------------------------------------
pub const COL_TS: &str = "Timestamp (ns)";
pub const COL_TYPE: &str = "Event Type";
pub const COL_NAME: &str = "Name";
pub const COL_PROC: &str = "Process";
pub const COL_THREAD: &str = "Thread";
pub const COL_PARTNER: &str = "Partner";
pub const COL_MSG_SIZE: &str = "Msg Size";
pub const COL_TAG: &str = "Tag";

// -- canonical event-type / instant-event names ---------------------------
pub const ENTER: &str = "Enter";
pub const LEAVE: &str = "Leave";
pub const INSTANT: &str = "Instant";
/// Instant event marking a point-to-point send (Partner = destination).
pub const SEND_EVENT: &str = "MpiSend";
/// Instant event marking a point-to-point receive (Partner = source).
pub const RECV_EVENT: &str = "MpiRecv";

/// Names treated as communication functions by default (paper §IV.C/D);
/// `idle_time` and `comm_comp_breakdown` accept overrides.
pub const DEFAULT_COMM_FUNCTIONS: &[&str] = &[
    "MPI_Send", "MPI_Isend", "MPI_Recv", "MPI_Irecv", "MPI_Wait",
    "MPI_Waitall", "MPI_Barrier", "MPI_Allreduce", "MPI_Reduce",
    "MPI_Bcast", "MPI_Alltoall", "MPI_Allgather", "MPI_Sendrecv",
    "ncclAllReduce", "ncclAllGather", "ncclSend", "ncclRecv",
];

/// Names treated as *idle / waiting* by default for `idle_time`.
pub const DEFAULT_IDLE_FUNCTIONS: &[&str] =
    &["MPI_Recv", "MPI_Wait", "MPI_Waitall", "MPI_Barrier", "Idle"];

/// Is `name` a derived (analysis-cached) column rather than base trace
/// data? `_matching_event` / `_parent` / `_depth` hold absolute row
/// indices and become stale whenever rows are subset; `time.inc` /
/// `time.exc` change when a call's children are filtered away. Row
/// subsetting (filters, shards) drops these so they recompute.
pub(crate) fn is_derived_column(name: &str) -> bool {
    name.starts_with('_') || name == "time.inc" || name == "time.exc"
}

/// Provenance metadata carried alongside the events table.
#[derive(Debug, Clone, Default)]
pub struct TraceMeta {
    /// Which reader produced this trace ("otf2", "csv", ...).
    pub format: String,
    /// Source path, if read from disk.
    pub source: String,
    /// Application name, if the format records one.
    pub app: String,
}

/// A parallel execution trace: the events table + metadata.
///
/// This is the paper's `Trace` object. The events table is public — "users
/// can optionally access the underlying DataFrame to perform custom data
/// wrangling" (§I) — and every operation in [`crate::analysis`] takes the
/// trace by reference.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Table,
    pub meta: TraceMeta,
}

impl Trace {
    pub fn new(events: Table, meta: TraceMeta) -> Self {
        Trace { events, meta }
    }

    // -- format constructors (delegating to `readers`) --------------------

    /// Read a CSV trace (paper Fig. 1).
    pub fn from_csv(path: impl AsRef<Path>) -> Result<Trace> {
        crate::readers::csv::read(path.as_ref())
    }

    /// Read an OTF2-sim trace directory (see `readers::otf2`), using all
    /// available cores.
    pub fn from_otf2(path: impl AsRef<Path>) -> Result<Trace> {
        crate::readers::otf2::read(path.as_ref(), 0)
    }

    /// Read an OTF2-sim trace with an explicit reader-thread count.
    pub fn from_otf2_parallel(path: impl AsRef<Path>, threads: usize) -> Result<Trace> {
        crate::readers::otf2::read(path.as_ref(), threads)
    }

    /// Read a Projections-sim trace directory (Charm++ style).
    pub fn from_projections(path: impl AsRef<Path>) -> Result<Trace> {
        crate::readers::projections::read(path.as_ref(), 0)
    }

    /// Read a Chrome Trace Viewer JSON file (Nsight Systems / PyTorch
    /// Profiler exports).
    pub fn from_chrome(path: impl AsRef<Path>) -> Result<Trace> {
        crate::readers::chrome::read(path.as_ref())
    }

    /// Alias for [`Trace::from_chrome`] matching the paper's reader list.
    pub fn from_nsight(path: impl AsRef<Path>) -> Result<Trace> {
        Self::from_chrome(path)
    }

    /// Read an HPCToolkit-sim database directory (trace.db + meta.db).
    pub fn from_hpctoolkit(path: impl AsRef<Path>) -> Result<Trace> {
        crate::readers::hpctoolkit::read(path.as_ref())
    }

    // -- basic accessors ---------------------------------------------------

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn timestamps(&self) -> Result<&[i64]> {
        self.events.i64s(COL_TS)
    }

    pub fn processes(&self) -> Result<&[i64]> {
        self.events.i64s(COL_PROC)
    }

    /// Distinct process ids, sorted.
    pub fn process_ids(&self) -> Result<Vec<i64>> {
        let mut ids: Vec<i64> = self.processes()?.to_vec();
        ids.sort_unstable();
        ids.dedup();
        Ok(ids)
    }

    /// Number of distinct processes.
    pub fn num_processes(&self) -> Result<usize> {
        Ok(self.process_ids()?.len())
    }

    /// (min, max) timestamp over all events; (0, 0) for empty traces.
    pub fn time_range(&self) -> Result<(i64, i64)> {
        let ts = self.timestamps()?;
        if ts.is_empty() {
            return Ok((0, 0));
        }
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for &t in ts {
            lo = lo.min(t);
            hi = hi.max(t);
        }
        Ok((lo, hi))
    }

    /// Wall-clock span covered by the trace in ns.
    pub fn duration_ns(&self) -> Result<i64> {
        let (lo, hi) = self.time_range()?;
        Ok(hi - lo)
    }

    /// Filter to a sub-trace (paper §IV.E): a new `Trace` with the reduced
    /// events table; every analysis op applies to the result unchanged.
    ///
    /// Cached derived columns (`_matching_event`, `_parent`, `_depth`,
    /// `time.inc`, `time.exc`) are dropped: the index-valued ones point
    /// at rows of *this* trace and would be stale in the sub-trace, and
    /// exclusive times change when calls lose children to the filter.
    /// Analyses on the sub-trace recompute them from scratch.
    pub fn filter(&self, e: &Expr) -> Result<Trace> {
        let mask = self.events.mask(e)?;
        let mut events = crate::df::Table::new();
        for name in self.events.names() {
            if is_derived_column(name) {
                continue;
            }
            events.push(name, self.events.col(name)?.filter(&mask))?;
        }
        Ok(Trace { events, meta: self.meta.clone() })
    }

    /// [`Trace::filter`] with columns materialized concurrently on the
    /// worker pool (`threads`: 0 = available parallelism). Identical
    /// output to the sequential filter. (Deliberately does not reuse
    /// [`crate::df::Table::par_filter`]: going through `select` first
    /// would clone every kept column at full length just to drop the
    /// derived ones.)
    pub fn par_filter(&self, e: &Expr, threads: usize) -> Result<Trace> {
        let mask = self.events.mask(e)?;
        let keep: Vec<&String> = self
            .events
            .names()
            .iter()
            .filter(|n| !is_derived_column(n))
            .collect();
        let cols = crate::exec::pool::run_indexed(keep.len(), threads, |i| {
            Ok(self.events.col(keep[i])?.filter(&mask))
        })?;
        let mut events = crate::df::Table::new();
        for (n, c) in keep.into_iter().zip(cols) {
            events.push(n, c)?;
        }
        Ok(Trace { events, meta: self.meta.clone() })
    }

    /// Rows (event indices) for one process, in table order.
    pub fn rows_of_process(&self, p: i64) -> Result<Vec<u32>> {
        Ok(self
            .processes()?
            .iter()
            .enumerate()
            .filter(|(_, &q)| q == p)
            .map(|(i, _)| i as u32)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Trace {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.enter(0, 0, 10, "foo");
        b.leave(0, 0, 50, "foo");
        b.leave(0, 0, 100, "main");
        b.enter(1, 0, 0, "main");
        b.leave(1, 0, 90, "main");
        b.finish()
    }

    #[test]
    fn accessors() {
        let t = toy();
        assert_eq!(t.len(), 6);
        assert_eq!(t.num_processes().unwrap(), 2);
        assert_eq!(t.process_ids().unwrap(), vec![0, 1]);
        assert_eq!(t.time_range().unwrap(), (0, 100));
        assert_eq!(t.duration_ns().unwrap(), 100);
    }

    #[test]
    fn filter_returns_full_trace_object() {
        let t = toy();
        let sub = t.filter(&Expr::process_eq(1)).unwrap();
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.num_processes().unwrap(), 1);
        // All ops still apply — the schema is intact.
        assert_eq!(sub.events.names(), t.events.names());
    }

    #[test]
    fn rows_of_process() {
        let t = toy();
        assert_eq!(t.rows_of_process(0).unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(t.rows_of_process(1).unwrap(), vec![4, 5]);
    }

    #[test]
    fn filter_drops_cached_derived_columns() {
        // Derived columns hold absolute row indices / whole-trace values;
        // carrying them into a row subset would poison later analyses.
        let mut t = toy();
        crate::analysis::metrics::calc_exc_metrics(&mut t).unwrap();
        assert!(t.events.has("_matching_event") && t.events.has("time.exc"));
        for sub in [
            t.filter(&Expr::process_eq(0)).unwrap(),
            t.par_filter(&Expr::process_eq(0), 4).unwrap(),
        ] {
            assert!(!sub.events.has("_matching_event"));
            assert!(!sub.events.has("time.exc"));
            let mut sub = sub;
            let fp =
                crate::analysis::flat_profile(&mut sub, crate::analysis::Metric::ExcTime).unwrap();
            assert!(!fp.is_empty());
        }
    }
}
