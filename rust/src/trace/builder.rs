//! Construct traces in the canonical schema.
//!
//! Used by every reader and by the synthetic application models in
//! [`crate::gen`]. The builder buffers rows, then sorts into canonical
//! (Process, Thread, Timestamp) order and assembles the columnar table in
//! one pass.

use super::*;
use crate::df::{interner::NULL_CODE, Column, Interner, StrCode, Table, NULL_I64};
use std::sync::Arc;

/// One buffered event row.
#[derive(Debug, Clone, Copy)]
struct Row {
    ts: i64,
    etype: StrCode,
    name: StrCode,
    proc: i64,
    thread: i64,
    partner: i64,
    msg_size: i64,
    tag: i64,
}

/// Incremental trace builder.
#[derive(Debug)]
pub struct TraceBuilder {
    rows: Vec<Row>,
    names: Interner,
    etypes: Interner,
    enter_code: StrCode,
    leave_code: StrCode,
    instant_code: StrCode,
    meta: TraceMeta,
    /// If true (default), `finish` sorts rows into canonical order; readers
    /// whose input is already canonical disable it.
    pub sort_on_finish: bool,
}

impl Default for TraceBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceBuilder {
    pub fn new() -> Self {
        let mut etypes = Interner::new();
        let enter_code = etypes.intern(ENTER);
        let leave_code = etypes.intern(LEAVE);
        let instant_code = etypes.intern(INSTANT);
        TraceBuilder {
            rows: Vec::new(),
            names: Interner::new(),
            etypes,
            enter_code,
            leave_code,
            instant_code,
            meta: TraceMeta::default(),
            sort_on_finish: true,
        }
    }

    /// Pre-size the row buffer.
    pub fn with_capacity(n: usize) -> Self {
        let mut b = Self::new();
        b.rows.reserve(n);
        b
    }

    pub fn set_meta(&mut self, meta: TraceMeta) {
        self.meta = meta;
    }

    /// Intern a function name ahead of time (for readers with definition
    /// tables; makes codes independent of event order).
    pub fn define_name(&mut self, name: &str) -> StrCode {
        self.names.intern(name)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    // -- event emission ----------------------------------------------------

    pub fn enter(&mut self, proc: i64, thread: i64, ts: i64, name: &str) {
        let name = self.names.intern(name);
        self.enter_coded(proc, thread, ts, name);
    }

    pub fn leave(&mut self, proc: i64, thread: i64, ts: i64, name: &str) {
        let name = self.names.intern(name);
        self.leave_coded(proc, thread, ts, name);
    }

    /// Enter with a pre-interned name code (hot path for binary readers).
    pub fn enter_coded(&mut self, proc: i64, thread: i64, ts: i64, name: StrCode) {
        self.rows.push(Row {
            ts,
            etype: self.enter_code,
            name,
            proc,
            thread,
            partner: NULL_I64,
            msg_size: NULL_I64,
            tag: NULL_I64,
        });
    }

    /// Leave with a pre-interned name code.
    pub fn leave_coded(&mut self, proc: i64, thread: i64, ts: i64, name: StrCode) {
        self.rows.push(Row {
            ts,
            etype: self.leave_code,
            name,
            proc,
            thread,
            partner: NULL_I64,
            msg_size: NULL_I64,
            tag: NULL_I64,
        });
    }

    /// Generic instant event (no message payload).
    pub fn instant(&mut self, proc: i64, thread: i64, ts: i64, name: &str) {
        let name = self.names.intern(name);
        self.rows.push(Row {
            ts,
            etype: self.instant_code,
            name,
            proc,
            thread,
            partner: NULL_I64,
            msg_size: NULL_I64,
            tag: NULL_I64,
        });
    }

    /// Point-to-point send record (emit inside the sending MPI call).
    pub fn send(&mut self, proc: i64, thread: i64, ts: i64, dest: i64, bytes: i64, tag: i64) {
        let name = self.names.intern(SEND_EVENT);
        self.rows.push(Row {
            ts,
            etype: self.instant_code,
            name,
            proc,
            thread,
            partner: dest,
            msg_size: bytes,
            tag,
        });
    }

    /// Point-to-point receive record (emit inside the receiving MPI call).
    pub fn recv(&mut self, proc: i64, thread: i64, ts: i64, src: i64, bytes: i64, tag: i64) {
        let name = self.names.intern(RECV_EVENT);
        self.rows.push(Row {
            ts,
            etype: self.instant_code,
            name,
            proc,
            thread,
            partner: src,
            msg_size: bytes,
            tag,
        });
    }

    /// Finish: sort canonically (unless disabled) and build the table.
    pub fn finish(self) -> Trace {
        let mut rows = self.rows;
        if self.sort_on_finish {
            // stable: preserves emission order for equal timestamps, which
            // keeps Enter before nested Enter at identical times.
            rows.sort_by_key(|r| (r.proc, r.thread, r.ts));
        }
        let n = rows.len();
        let mut ts = Vec::with_capacity(n);
        let mut et = Vec::with_capacity(n);
        let mut nm = Vec::with_capacity(n);
        let mut pr = Vec::with_capacity(n);
        let mut th = Vec::with_capacity(n);
        let mut pa = Vec::with_capacity(n);
        let mut ms = Vec::with_capacity(n);
        let mut tg = Vec::with_capacity(n);
        for r in &rows {
            ts.push(r.ts);
            et.push(r.etype);
            nm.push(r.name);
            pr.push(r.proc);
            th.push(r.thread);
            pa.push(r.partner);
            ms.push(r.msg_size);
            tg.push(r.tag);
        }
        let names = Arc::new(self.names);
        let etypes = Arc::new(self.etypes);
        let mut t = Table::new();
        t.push(COL_TS, Column::I64(ts)).unwrap();
        t.push(COL_TYPE, Column::Str { codes: et, dict: etypes }).unwrap();
        t.push(COL_NAME, Column::Str { codes: nm, dict: names }).unwrap();
        t.push(COL_PROC, Column::I64(pr)).unwrap();
        t.push(COL_THREAD, Column::I64(th)).unwrap();
        t.push(COL_PARTNER, Column::I64(pa)).unwrap();
        t.push(COL_MSG_SIZE, Column::I64(ms)).unwrap();
        t.push(COL_TAG, Column::I64(tg)).unwrap();
        Trace::new(t, self.meta)
    }
}

/// Assert structural well-formedness of a trace: per (process, thread),
/// Enter/Leave events must nest like balanced parentheses. Returns the
/// maximum call-stack depth seen. Used by generator tests and reader
/// round-trip tests.
pub fn validate_nesting(trace: &Trace) -> anyhow::Result<usize> {
    use anyhow::bail;
    let ts = trace.events.i64s(COL_TS)?;
    let pr = trace.events.i64s(COL_PROC)?;
    let th = trace.events.i64s(COL_THREAD)?;
    let (et, edict) = trace.events.strs(COL_TYPE)?;
    let (nm, _) = trace.events.strs(COL_NAME)?;
    let enter = edict.code_of(ENTER).unwrap_or(NULL_CODE);
    let leave = edict.code_of(LEAVE).unwrap_or(NULL_CODE);

    let mut stacks: std::collections::HashMap<(i64, i64), Vec<(StrCode, i64)>> =
        std::collections::HashMap::new();
    let mut max_depth = 0usize;
    for i in 0..trace.len() {
        let key = (pr[i], th[i]);
        let stack = stacks.entry(key).or_default();
        if et[i] == enter {
            if let Some(&(_, top_ts)) = stack.last() {
                if ts[i] < top_ts {
                    bail!("event {i}: enter goes back in time");
                }
            }
            stack.push((nm[i], ts[i]));
            max_depth = max_depth.max(stack.len());
        } else if et[i] == leave {
            match stack.pop() {
                Some((code, enter_ts)) => {
                    if code != nm[i] {
                        bail!("event {i}: leave does not match top of stack");
                    }
                    if ts[i] < enter_ts {
                        bail!("event {i}: leave before enter");
                    }
                }
                None => bail!("event {i}: leave with empty stack"),
            }
        }
    }
    for ((p, t), stack) in &stacks {
        if !stack.is_empty() {
            bail!("process {p} thread {t}: {} unclosed enters", stack.len());
        }
    }
    Ok(max_depth)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_canonical_order() {
        let mut b = TraceBuilder::new();
        // emit out of order on purpose
        b.enter(1, 0, 5, "main");
        b.leave(1, 0, 9, "main");
        b.enter(0, 0, 0, "main");
        b.leave(0, 0, 10, "main");
        let t = b.finish();
        assert_eq!(t.events.i64s(COL_PROC).unwrap(), &[0, 0, 1, 1]);
        assert_eq!(t.events.i64s(COL_TS).unwrap(), &[0, 10, 5, 9]);
    }

    #[test]
    fn send_recv_carry_payload() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "MPI_Send");
        b.send(0, 0, 1, 3, 1024, 7);
        b.leave(0, 0, 2, "MPI_Send");
        let t = b.finish();
        let pa = t.events.i64s(COL_PARTNER).unwrap();
        let ms = t.events.i64s(COL_MSG_SIZE).unwrap();
        assert_eq!(pa[1], 3);
        assert_eq!(ms[1], 1024);
        assert_eq!(pa[0], NULL_I64); // function events carry no payload
    }

    #[test]
    fn validate_nesting_accepts_wellformed() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.enter(0, 0, 1, "foo");
        b.leave(0, 0, 2, "foo");
        b.enter(0, 0, 3, "foo");
        b.leave(0, 0, 4, "foo");
        b.leave(0, 0, 5, "main");
        let t = b.finish();
        assert_eq!(validate_nesting(&t).unwrap(), 2);
    }

    #[test]
    fn validate_nesting_rejects_mismatch() {
        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main");
        b.leave(0, 0, 1, "foo"); // wrong name
        let t = b.finish();
        assert!(validate_nesting(&t).is_err());

        let mut b = TraceBuilder::new();
        b.enter(0, 0, 0, "main"); // never left
        let t = b.finish();
        assert!(validate_nesting(&t).is_err());

        let mut b = TraceBuilder::new();
        b.leave(0, 0, 0, "main"); // leave before enter
        let t = b.finish();
        assert!(validate_nesting(&t).is_err());
    }
}
