//! Minimal offline reimplementation of the `anyhow` API surface Pipit-RS
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Differences from the real crate are deliberate simplifications: the
//! error is a flattened chain of messages (no downcasting, no
//! backtraces). `Display` shows the outermost message; the alternate form
//! (`{:#}`) shows the whole chain joined by `": "`, matching how the CLI
//! prints errors.

use std::fmt;

/// A flattened error: the outermost message first, then each `source` /
/// context layer below it.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message (what `to_string()` returns).
    pub fn root_message(&self) -> &str {
        self.chain.first().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that is what makes the blanket `From` below coherent (same trick as
// the real anyhow).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("reading defs.bin");
        let e = r.unwrap_err();
        assert_eq!(e.to_string(), "reading defs.bin");
        assert_eq!(format!("{e:#}"), "reading defs.bin: no such file");
    }

    #[test]
    fn option_context_and_with_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        let some = Some(7u32);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(inner(-2).unwrap_err().to_string(), "negative input -2");
        let e = anyhow!("custom {}", 42);
        assert_eq!(e.to_string(), "custom 42");
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn nested_context_chain() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: inner");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "mid", "inner"]);
    }
}
