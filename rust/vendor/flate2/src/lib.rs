//! Offline reimplementation of the `flate2` API subset Pipit-RS uses:
//! [`Compression`], [`write::ZlibEncoder`], [`read::ZlibDecoder`].
//!
//! The encoder emits valid zlib framing around *stored* deflate blocks
//! (no entropy coding — compression level is accepted but ignored), so
//! any standard inflater reads its output. The decoder implements full
//! inflate (stored + fixed + dynamic Huffman, [`inflate`]) with adler32
//! verification, so it reads streams from any standard compressor too.
//! Corruption — truncation, header damage, checksum mismatch — is
//! reported as `io::ErrorKind::InvalidData`, which is the contract the
//! failure-injection tests rely on.

pub mod inflate;

/// Compression level. Accepted for API compatibility; the stored-block
/// encoder ignores it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn none() -> Compression {
        Compression(0)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }

    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

pub mod write {
    use std::io::{self, Write};

    /// Zlib encoder wrapping a writer. Data is buffered and the zlib
    /// stream is emitted by [`ZlibEncoder::finish`] (all call sites in
    /// this workspace call `finish`; nothing is written on drop).
    pub struct ZlibEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> ZlibEncoder<W> {
        pub fn new(inner: W, _level: crate::Compression) -> ZlibEncoder<W> {
            ZlibEncoder { inner, buf: Vec::new() }
        }

        /// Emit the complete zlib stream and return the inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            let payload = crate::inflate::zlib_compress_stored(&self.buf);
            self.inner.write_all(&payload)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for ZlibEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use std::io::{self, Read};

    /// Zlib decoder wrapping a reader. The whole inner stream is read
    /// and inflated on first use; corruption anywhere (including an
    /// adler32 mismatch) surfaces as `InvalidData`.
    pub struct ZlibDecoder<R: Read> {
        inner: R,
        out: Option<Vec<u8>>,
        pos: usize,
    }

    impl<R: Read> ZlibDecoder<R> {
        pub fn new(inner: R) -> ZlibDecoder<R> {
            ZlibDecoder { inner, out: None, pos: 0 }
        }

        fn fill(&mut self) -> io::Result<()> {
            if self.out.is_none() {
                let mut raw = Vec::new();
                self.inner.read_to_end(&mut raw)?;
                let data = crate::inflate::zlib_decompress(&raw)
                    .map_err(|m| io::Error::new(io::ErrorKind::InvalidData, m))?;
                self.out = Some(data);
            }
            Ok(())
        }
    }

    impl<R: Read> Read for ZlibDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            self.fill()?;
            let data = self.out.as_ref().expect("filled above");
            let n = (data.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc = write::ZlibEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(data).unwrap();
        let compressed = enc.finish().unwrap();
        let mut out = Vec::new();
        read::ZlibDecoder::new(&compressed[..]).read_to_end(&mut out).unwrap();
        out
    }

    /// Tiny deterministic byte generator for incompressible-ish data.
    fn lcg_bytes(n: usize) -> Vec<u8> {
        let mut x = 0x2545F4914F6CDD1Du64;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                (x >> 56) as u8
            })
            .collect()
    }

    #[test]
    fn roundtrip_small_and_empty() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"hello zlib"), b"hello zlib");
    }

    #[test]
    fn roundtrip_multi_block() {
        // > 65535 bytes forces several stored blocks
        let data = lcg_bytes(200_000);
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn decodes_real_zlib_fixed_huffman_stream() {
        // zlib.compress(bytes(range(256)) * 4, 1) — fixed-Huffman blocks
        let compressed: &[u8] = &[
            120, 1, 99, 96, 100, 98, 102, 97, 101, 99, 231, 224, 228, 226, 230, 225, 229, 227,
            23, 16, 20, 18, 22, 17, 21, 19, 151, 144, 148, 146, 150, 145, 149, 147, 87, 80, 84,
            82, 86, 81, 85, 83, 215, 208, 212, 210, 214, 209, 213, 211, 55, 48, 52, 50, 54, 49,
            53, 51, 183, 176, 180, 178, 182, 177, 181, 179, 119, 112, 116, 114, 118, 113, 117,
            115, 247, 240, 244, 242, 246, 241, 245, 243, 15, 8, 12, 10, 14, 9, 13, 11, 143, 136,
            140, 138, 142, 137, 141, 139, 79, 72, 76, 74, 78, 73, 77, 75, 207, 200, 204, 202,
            206, 201, 205, 203, 47, 40, 44, 42, 46, 41, 45, 43, 175, 168, 172, 170, 174, 169,
            173, 171, 111, 104, 108, 106, 110, 105, 109, 107, 239, 232, 236, 234, 238, 233, 237,
            235, 159, 48, 113, 210, 228, 41, 83, 167, 77, 159, 49, 115, 214, 236, 57, 115, 231,
            205, 95, 176, 112, 209, 226, 37, 75, 151, 45, 95, 177, 114, 213, 234, 53, 107, 215,
            173, 223, 176, 113, 211, 230, 45, 91, 183, 109, 223, 177, 115, 215, 238, 61, 123,
            247, 237, 63, 112, 240, 208, 225, 35, 71, 143, 29, 63, 113, 242, 212, 233, 51, 103,
            207, 157, 191, 112, 241, 210, 229, 43, 87, 175, 93, 191, 113, 243, 214, 237, 59,
            119, 239, 221, 127, 240, 240, 209, 227, 39, 79, 159, 61, 127, 241, 242, 213, 235,
            55, 111, 223, 189, 255, 240, 241, 211, 231, 47, 95, 191, 125, 255, 241, 243, 215,
            239, 63, 127, 255, 253, 103, 24, 245, 255, 104, 252, 143, 224, 244, 15, 0, 228, 201,
            254, 16,
        ];
        let mut want = Vec::new();
        for _ in 0..4 {
            want.extend(0u8..=255);
        }
        let mut out = Vec::new();
        read::ZlibDecoder::new(compressed).read_to_end(&mut out).unwrap();
        assert_eq!(out, want);
    }

    #[test]
    fn decodes_real_zlib_dynamic_huffman_stream() {
        // zlib.compress(b"the quick brown fox jumps over the lazy dog " * 8, 6)
        let compressed: &[u8] = &[
            120, 156, 43, 201, 72, 85, 40, 44, 205, 76, 206, 86, 72, 42, 202, 47, 207, 83, 72,
            203, 175, 80, 200, 42, 205, 45, 40, 86, 200, 47, 75, 45, 82, 40, 1, 74, 231, 36, 86,
            85, 42, 164, 228, 167, 131, 57, 163, 106, 73, 83, 11, 0, 7, 191, 128, 201,
        ];
        let want: Vec<u8> = b"the quick brown fox jumps over the lazy dog "
            .iter()
            .copied()
            .collect::<Vec<u8>>()
            .repeat(8);
        let mut out = Vec::new();
        read::ZlibDecoder::new(compressed).read_to_end(&mut out).unwrap();
        assert_eq!(out, want);
    }

    #[test]
    fn truncation_is_an_error() {
        let mut enc = write::ZlibEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&lcg_bytes(4096)).unwrap();
        let compressed = enc.finish().unwrap();
        for cut in [1usize, 2, 6, compressed.len() / 2, compressed.len() - 1] {
            let mut out = Vec::new();
            let err = read::ZlibDecoder::new(&compressed[..cut]).read_to_end(&mut out);
            assert!(err.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn bitflip_is_an_error() {
        let mut enc = write::ZlibEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&lcg_bytes(4096)).unwrap();
        let mut compressed = enc.finish().unwrap();
        let mid = compressed.len() / 2;
        compressed[mid] ^= 0xFF;
        let mut out = Vec::new();
        assert!(read::ZlibDecoder::new(&compressed[..]).read_to_end(&mut out).is_err());
    }

    #[test]
    fn adler32_reference_value() {
        let data: Vec<u8> = b"the quick brown fox jumps over the lazy dog "
            .iter()
            .copied()
            .collect::<Vec<u8>>()
            .repeat(8);
        assert_eq!(inflate::adler32(&data), 0x07bf_80c9);
    }
}
