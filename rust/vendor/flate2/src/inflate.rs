//! DEFLATE (RFC 1951) decoder + zlib (RFC 1950) framing with adler32
//! verification. Handles stored, fixed-Huffman, and dynamic-Huffman
//! blocks, so it reads streams produced by any standard zlib compressor,
//! not only this crate's stored-block writer.

/// Checksum over `data` (RFC 1950 §8.2). Deferred modulo: 5552 is the
/// largest n with 255*n*(n+1)/2 + (n+1)*(65521-1) < 2^32.
pub fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    for chunk in data.chunks(5552) {
        for &x in chunk {
            a += x as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

struct BitReader<'a> {
    data: &'a [u8],
    /// Next byte index.
    pos: usize,
    /// Bits already consumed from `data[pos]`.
    bit: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8], pos: usize) -> Self {
        BitReader { data, pos, bit: 0 }
    }

    /// Read `n` bits, LSB-first (n <= 16).
    fn bits(&mut self, n: u32) -> Result<u32, String> {
        let mut out = 0u32;
        for i in 0..n {
            let byte = *self
                .data
                .get(self.pos)
                .ok_or_else(|| "unexpected end of deflate stream".to_string())?;
            out |= (((byte >> self.bit) & 1) as u32) << i;
            self.bit += 1;
            if self.bit == 8 {
                self.bit = 0;
                self.pos += 1;
            }
        }
        Ok(out)
    }

    /// Discard bits up to the next byte boundary.
    fn align(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.pos += 1;
        }
    }

    /// Read `n` whole bytes (must be byte-aligned).
    fn bytes(&mut self, n: usize) -> Result<&'a [u8], String> {
        debug_assert_eq!(self.bit, 0);
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| "unexpected end of deflate stream".to_string())?;
        let out = &self.data[self.pos..end];
        self.pos = end;
        Ok(out)
    }
}

/// Canonical Huffman decoding table: symbol counts per code length and
/// symbols sorted by (length, symbol) — the RFC 1951 §3.2.2 construction.
struct Huffman {
    counts: [u16; 16],
    symbols: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u8]) -> Result<Huffman, String> {
        let mut counts = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err("code length > 15".into());
            }
            counts[l as usize] += 1;
        }
        // over-subscription check (incomplete codes are permitted)
        let mut left: i32 = 1;
        for len in 1..16 {
            left <<= 1;
            left -= counts[len] as i32;
            if left < 0 {
                return Err("over-subscribed huffman code".into());
            }
        }
        // offsets of each length's first symbol in the sorted table
        let mut offs = [0usize; 16];
        for len in 1..15 {
            offs[len + 1] = offs[len] + counts[len] as usize;
        }
        let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l != 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbols[offs[l as usize]] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    fn decode(&self, br: &mut BitReader) -> Result<u16, String> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..16 {
            code |= br.bits(1)? as i32;
            let count = self.counts[len] as i32;
            if code - first < count {
                return Ok(self.symbols[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err("invalid huffman code".into())
    }
}

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

fn inflate_block(
    lit: &Huffman,
    dist: &Huffman,
    br: &mut BitReader,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    loop {
        let sym = lit.decode(br)?;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == 256 {
            return Ok(());
        } else {
            let s = (sym - 257) as usize;
            if s >= 29 {
                return Err("invalid length code".into());
            }
            let len = LEN_BASE[s] as usize + br.bits(LEN_EXTRA[s])? as usize;
            let d = dist.decode(br)? as usize;
            if d >= 30 {
                return Err("invalid distance code".into());
            }
            let back = DIST_BASE[d] as usize + br.bits(DIST_EXTRA[d])? as usize;
            if back > out.len() {
                return Err("distance beyond output start".into());
            }
            let start = out.len() - back;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
}

fn fixed_tables() -> (Huffman, Huffman) {
    let mut lit_lengths = [0u8; 288];
    for (i, l) in lit_lengths.iter_mut().enumerate() {
        *l = match i {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    let dist_lengths = [5u8; 30];
    (
        Huffman::build(&lit_lengths).expect("fixed literal table"),
        Huffman::build(&dist_lengths).expect("fixed distance table"),
    )
}

fn dynamic_tables(br: &mut BitReader) -> Result<(Huffman, Huffman), String> {
    let hlit = br.bits(5)? as usize + 257;
    let hdist = br.bits(5)? as usize + 1;
    let hclen = br.bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err("too many huffman codes".into());
    }
    const ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];
    let mut cl = [0u8; 19];
    for &slot in ORDER.iter().take(hclen) {
        cl[slot] = br.bits(3)? as u8;
    }
    let clh = Huffman::build(&cl)?;
    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0usize;
    while i < lengths.len() {
        let sym = clh.decode(br)?;
        match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err("repeat with no previous length".into());
                }
                let prev = lengths[i - 1];
                let rep = 3 + br.bits(2)? as usize;
                if i + rep > lengths.len() {
                    return Err("length repeat overflows".into());
                }
                for _ in 0..rep {
                    lengths[i] = prev;
                    i += 1;
                }
            }
            17 | 18 => {
                let rep = if sym == 17 {
                    3 + br.bits(3)? as usize
                } else {
                    11 + br.bits(7)? as usize
                };
                if i + rep > lengths.len() {
                    return Err("length repeat overflows".into());
                }
                i += rep; // already zero
            }
            _ => return Err("invalid code-length symbol".into()),
        }
    }
    if lengths[256] == 0 {
        return Err("no end-of-block code".into());
    }
    Ok((Huffman::build(&lengths[..hlit])?, Huffman::build(&lengths[hlit..])?))
}

/// Decompress a full zlib stream (header + deflate + adler32), verifying
/// the checksum. Errors on truncation, corruption, preset dictionaries,
/// and checksum mismatches.
pub fn zlib_decompress(input: &[u8]) -> Result<Vec<u8>, String> {
    if input.len() < 2 {
        return Err("zlib stream shorter than its header".into());
    }
    let cmf = input[0];
    let flg = input[1];
    if cmf & 0x0f != 8 {
        return Err(format!("unsupported compression method {}", cmf & 0x0f));
    }
    if ((cmf as u32) * 256 + flg as u32) % 31 != 0 {
        return Err("zlib header check failed".into());
    }
    if flg & 0x20 != 0 {
        return Err("preset dictionaries are not supported".into());
    }
    let mut br = BitReader::new(input, 2);
    let mut out = Vec::with_capacity(input.len().saturating_mul(3));
    loop {
        let bfinal = br.bits(1)?;
        let btype = br.bits(2)?;
        match btype {
            0 => {
                br.align();
                let hdr = br.bytes(4)?;
                let len = u16::from_le_bytes([hdr[0], hdr[1]]);
                let nlen = u16::from_le_bytes([hdr[2], hdr[3]]);
                if len != !nlen {
                    return Err("stored block length check failed".into());
                }
                let body = br.bytes(len as usize)?;
                out.extend_from_slice(body);
            }
            1 => {
                let (lit, dist) = fixed_tables();
                inflate_block(&lit, &dist, &mut br, &mut out)?;
            }
            2 => {
                let (lit, dist) = dynamic_tables(&mut br)?;
                inflate_block(&lit, &dist, &mut br, &mut out)?;
            }
            _ => return Err("reserved block type".into()),
        }
        if bfinal == 1 {
            break;
        }
    }
    br.align();
    let tail = br.bytes(4).map_err(|_| "truncated adler32 checksum".to_string())?;
    let want = u32::from_be_bytes([tail[0], tail[1], tail[2], tail[3]]);
    let got = adler32(&out);
    if want != got {
        return Err(format!("adler32 mismatch: stream says {want:#010x}, data is {got:#010x}"));
    }
    Ok(out)
}

/// Compress `data` as a zlib stream of stored (uncompressed) deflate
/// blocks — valid zlib that any inflater reads; no entropy coding.
pub fn zlib_compress_stored(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() + data.len() / 65_535 * 5 + 16);
    out.push(0x78);
    out.push(0x01); // (0x7801 % 31) == 0
    if data.is_empty() {
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xff, 0xff]);
    } else {
        let mut chunks = data.chunks(65_535).peekable();
        while let Some(c) = chunks.next() {
            out.push(if chunks.peek().is_none() { 0x01 } else { 0x00 });
            let len = c.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(c);
        }
    }
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}
