//! Stub of the `xla` PJRT bindings.
//!
//! The offline build environment has no XLA/PJRT shared library, so this
//! crate provides just enough of the API surface for
//! `pipit::runtime` to compile. [`PjRtClient::cpu`] always fails, which
//! makes `Runtime::load` return an error and every coordinator operation
//! take its pure-Rust engine — the behavior the analysis paths are
//! integration-tested to be equivalent to. Replace this path dependency
//! with the real bindings to enable the AOT HLO engines; no pipit code
//! changes are needed.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime unavailable: built against the stub xla crate (vendor/xla); \
         analysis falls back to the pure-Rust engines"
            .to_string(),
    ))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: Copy>(_value: T) -> Literal {
        Literal
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_cleanly() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("stub"));
    }
}
