//! Time-profile case study (paper §IV.B, Fig. 2): Tortuga on 64 processes,
//! rendered as the stacked-bar view — computed through the AOT Pallas
//! time-hist kernel via PJRT when artifacts are present.
//!
//! ```sh
//! make artifacts && cargo run --release --example time_profile_study
//! ```

use pipit::coordinator::AnalysisSession;
use pipit::gen::GenConfig;
use pipit::util::fmt_ns;
use pipit::viz::plot_time_profile;

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out)?;

    let mut s = AnalysisSession::new().with_artifacts("artifacts");
    println!(
        "PJRT kernel path: {}",
        if s.uses_hlo() { "ENABLED" } else { "disabled (pure Rust fallback)" }
    );

    s.generate("tortuga_64", "tortuga", &GenConfig::new(64, 12), 1)?;
    let tp = s.time_profile("tortuga_64", 128, None)?;

    println!(
        "time profile: {} bins x {} functions, total busy {}",
        tp.num_bins(),
        tp.func_names.len(),
        fmt_ns(tp.total())
    );
    // per-function share, like reading Fig. 2's stacked areas
    let mut totals: Vec<(String, f64)> = tp
        .func_names
        .iter()
        .enumerate()
        .map(|(f, name)| (name.clone(), tp.values.iter().map(|row| row[f]).sum()))
        .collect();
    totals.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\nshare of busy time:");
    for (name, v) in &totals {
        println!("  {:<24} {:>12}  {:>5.1}%", name, fmt_ns(*v), v / tp.total() * 100.0);
    }
    assert_eq!(totals[0].0, "computeRhs", "computeRhs dominates (paper Fig. 2)");

    std::fs::write(out.join("fig2_time_profile.svg"), plot_time_profile(&tp))?;
    println!("\n-> fig2_time_profile.svg");
    Ok(())
}
