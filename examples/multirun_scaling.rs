//! Multi-execution comparisons (paper §VII.D, Figs. 12–13):
//! * Tortuga scaling study 16→256 processes via `multi_run_analysis`,
//! * AxoNN communication/computation overlap across three optimization
//!   variants via `comm_comp_breakdown`.
//!
//! ```sh
//! cargo run --release --example multirun_scaling
//! ```

use pipit::analysis::{comm_comp_breakdown, multi_run_analysis, overlap, Metric};
use pipit::gen::{axonn, tortuga, GenConfig};
use pipit::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    // ---- Fig. 12: which functions scale poorly? ---------------------------
    // traces = [pipit.Trace.from_otf2('./tortuga/' + s) for s in sizes]
    let sizes = [16usize, 32, 64, 128, 256];
    let mut traces: Vec<_> = sizes
        .iter()
        .map(|&n| tortuga::generate(&GenConfig::new(n, 5)))
        .collect();
    // multirun_df = pipit.Trace.multirun_analysis(traces)
    let multirun_df = multi_run_analysis(&mut traces, Metric::ExcTime, 5)?;
    println!("Tortuga scaling study (total exclusive ns per function):\n");
    println!("{}", multirun_df.show());

    let rhs = multirun_df.func_names.iter().position(|f| f == "computeRhs").unwrap();
    let grad = multirun_df.func_names.iter().position(|f| f == "gradC2C").unwrap();
    let col = |f: usize| -> Vec<f64> { multirun_df.values.iter().map(|r| r[f]).collect() };
    let rhs_v = col(rhs);
    let grad_v = col(grad);
    println!("observations (paper §VII.D):");
    println!(
        "  * computeRhs grows {:.2}x from 32 to 64 procs (paper: 3.59e8 -> 4.53e8 = 1.26x)",
        rhs_v[2] / rhs_v[1]
    );
    println!(
        "  * gradC2C   grows {:.2}x from 32 to 64 procs (paper: 6.46e7 -> 1.05e8 = 1.63x)",
        grad_v[2] / grad_v[1]
    );
    println!("  * both plateau from 64 onwards: computeRhs {:.3e} / {:.3e} / {:.3e}",
        rhs_v[2], rhs_v[3], rhs_v[4]);
    assert!(rhs_v[2] / rhs_v[1] > 1.15, "32->64 jump expected");
    assert!((rhs_v[4] / rhs_v[2] - 1.0).abs() < 0.15, "plateau expected");

    // ---- Fig. 13: AxoNN overlap across variants ---------------------------
    println!("\nAxoNN comm/comp breakdown per iteration (8 GPUs, 3 variants):\n");
    println!(
        "{:>10} {:>14} {:>16} {:>14} {:>12}",
        "variant", "comp", "comp+comm ovl", "exposed comm", "iter time"
    );
    let mut iter_times = Vec::new();
    for v in 1..=3u32 {
        let mut t = axonn::generate(&GenConfig::new(8, 10), v);
        let per_proc = comm_comp_breakdown(&mut t, None, None)?;
        let b = overlap::mean_breakdown(&per_proc);
        let iter_ns = t.duration_ns()? as f64 / 10.0;
        iter_times.push(iter_ns);
        println!(
            "{:>10} {:>14} {:>16} {:>14} {:>12}",
            format!("v{v}"),
            fmt_ns(b.comp),
            fmt_ns(b.comp_overlapped),
            fmt_ns(b.comm),
            fmt_ns(iter_ns)
        );
    }
    println!("\nobservations (paper Fig. 13):");
    println!("  * v2 halves communication volume vs v1 (data-layout transposes)");
    println!("  * v3 overlaps communication with computation (async chunks)");
    println!(
        "  * per-iteration time improves v1 {} -> v2 {} -> v3 {}",
        fmt_ns(iter_times[0]),
        fmt_ns(iter_times[1]),
        fmt_ns(iter_times[2])
    );
    assert!(iter_times[0] > iter_times[1] && iter_times[1] > iter_times[2]);
    Ok(())
}
