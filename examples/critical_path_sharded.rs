//! Channel-sharded critical-path analysis, in memory and streamed.
//!
//! The critical path (paper §IV.D, Fig. 10) chases message dependencies
//! backwards from the last event, so it needs point-to-point matching —
//! historically a sequential single-trace walk. MPI's non-overtaking
//! guarantee makes every (src, dst, tag) channel independently
//! matchable, so matching now shards by channel across the worker pool
//! (`exec::ops::match_messages_sharded`), the backward walk itself runs
//! speculatively in parallel (per-process sub-paths stitched at matched
//! message edges), and the same analyses run over a `ShardedReader`
//! stream without ever materializing the trace: shards contribute
//! per-process runs and channel queues, channels pair-and-drain as the
//! census completes them — feeding the walk's speculation *during*
//! ingest — and the backward walk runs over O(processes + messages)
//! state. Results are bit-identical to the sequential engine on every
//! path (`tests/parity.rs`).
//!
//! ```sh
//! cargo run --release --example critical_path_sharded
//! ```

use pipit::analysis;
use pipit::coordinator::AnalysisSession;
use pipit::exec;
use pipit::gen::{self, GenConfig};
use pipit::readers::{open_sharded, otf2};
use pipit::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    // A 32-rank trace with real message traffic.
    let t = gen::generate("gol", &GenConfig::new(32, 20), 1)?;

    // ---- in-memory: sequential vs channel-sharded -------------------------
    let seq = analysis::critical_path_analysis(&mut t.clone())?;
    let sharded = exec::ops::critical_path(&t, 4)?;
    assert_eq!(seq[0].rows, sharded[0].rows, "bit-identical by construction");
    println!(
        "critical path: {} of {} events cross {} ranks",
        sharded[0].rows.len(),
        t.len(),
        t.num_processes()?
    );
    println!("\ntime along the path, by function:");
    for (name, ns) in sharded[0].time_by_function(&t)?.iter().take(5) {
        println!("  {name:<24} {}", fmt_ns(*ns));
    }

    // The matching itself is reusable for custom dataframe wrangling:
    let msgs = exec::ops::match_messages_sharded(&t, 4)?;
    println!(
        "\nmatched {} sends / {} recvs over channel-sharded FIFO pairing",
        msgs.sends.len(),
        msgs.recvs.len()
    );

    // ---- streamed: the trace never materializes ---------------------------
    // Write the trace to an OTF2-sim archive and analyze it shard-at-a-
    // time: each rank file decodes on demand, contributes its process
    // run and channel queues, and is dropped before the next decodes.
    let dir = std::env::temp_dir().join("pipit_critical_path_example");
    std::fs::create_dir_all(&dir)?;
    let archive = dir.join("gol32_otf2");
    otf2::write(&t, &archive)?;

    let mut reader = open_sharded(&archive)?;
    let (paths, stats) = exec::stream::critical_path(reader.as_mut(), 4)?;
    assert_eq!(paths[0].rows, seq[0].rows);
    println!(
        "\nstreamed critical path over {} shards ({} rows total, {} peak resident)",
        stats.shards, stats.total_rows, stats.max_shard_rows
    );
    println!(
        "walk overlap: {} of {} message pairs matched during ingest",
        stats.walk_pairs_early,
        stats.walk_pairs_early + stats.walk_pairs_final
    );
    assert!(!stats.fallback, "otf2 streams one rank file per shard");

    // Through a session, stream-backed entries stay unmaterialized and
    // the streamability pre-scan verdict is cached across analyses:
    let mut s = AnalysisSession::new().with_threads(4);
    s.load_streamed("t", &archive)?;
    let paths = s.critical_path("t")?;
    let lat = s.lateness("t")?;
    println!(
        "\nsession (still stream-backed): path {} events, {} logical ops, \
         lateness max {}",
        paths[0].rows.len(),
        lat.len(),
        fmt_ns(
            analysis::lateness_by_process(&lat)
                .first()
                .map(|p| p.max_lateness)
                .unwrap_or(0.0)
        )
    );
    Ok(())
}
