//! Communication case studies (paper §VII.C intro figures):
//! * Fig. 3 — Laghos 32p comm matrix, linear + log heatmaps
//! * Fig. 4 — Laghos 32p message-size histogram (3 clusters)
//! * Fig. 6 — Kripke 32p communication by process (3 groups)
//!
//! ```sh
//! cargo run --release --example comm_analysis
//! ```

use pipit::analysis::{comm_by_process, comm_matrix, message_histogram, CommUnit};
use pipit::gen::{kripke, laghos, GenConfig};
use pipit::viz::heatmap::{plot_comm_matrix, Scale};
use pipit::viz::{plot_comm_by_process, plot_message_histogram};

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out)?;

    // ---- Fig. 3: Laghos comm matrix --------------------------------------
    let laghos_32 = laghos::generate(&GenConfig::new(32, 25));
    let m = comm_matrix(&laghos_32, CommUnit::Bytes)?;
    println!("Laghos 32p comm matrix: total {:.1} MiB", m.total() / (1 << 20) as f64);
    println!("  symmetric:            {}", m.is_symmetric());
    println!("  near-diagonal volume: {:.1}%", m.diagonal_fraction(4) * 100.0);
    std::fs::write(out.join("fig3_comm_matrix_linear.svg"), plot_comm_matrix(&m, Scale::Linear))?;
    std::fs::write(out.join("fig3_comm_matrix_log.svg"), plot_comm_matrix(&m, Scale::Log))?;
    println!("  -> fig3_comm_matrix_{{linear,log}}.svg");

    // ---- Fig. 4: message size histogram -----------------------------------
    let (counts, edges) = message_histogram(&laghos_32, 10)?;
    println!("\nLaghos 32p message histogram (paper Fig. 4 format):");
    println!("({:?},", counts);
    println!(" {:?})", edges.iter().map(|e| *e as i64).collect::<Vec<_>>());
    std::fs::write(out.join("fig4_msg_histogram.svg"), plot_message_histogram(&counts, &edges))?;
    let small = counts[0];
    let medium = counts[4];
    let large = counts[9];
    println!("  clusters: small={small} medium={medium} large={large}");
    assert!(small > 0 && medium > 0 && large > 0);
    assert_eq!(counts[2] + counts[6] + counts[7], 0, "gaps between clusters");

    // ---- Fig. 6: Kripke comm by process -----------------------------------
    let kripke_32 = kripke::generate(&GenConfig::new(32, 8));
    let rows = comm_by_process(&kripke_32, CommUnit::Bytes)?;
    let mut totals: Vec<i64> = rows.iter().map(|&(_, s, r)| (s + r) as i64).collect();
    totals.sort_unstable();
    totals.dedup();
    println!("\nKripke 32p comm-by-process: {} distinct volume groups", totals.len());
    for (i, v) in totals.iter().enumerate() {
        let members: Vec<i64> = rows
            .iter()
            .filter(|&&(_, s, r)| (s + r) as i64 == *v)
            .map(|&(p, _, _)| p)
            .collect();
        println!("  group {i}: {:>10} bytes x {} processes {:?}", v, members.len(), members);
    }
    std::fs::write(out.join("fig6_comm_by_process.svg"), plot_comm_by_process(&rows))?;
    assert_eq!(totals.len(), 3, "paper observes exactly three groups");
    println!("  -> fig6_comm_by_process.svg");
    Ok(())
}
