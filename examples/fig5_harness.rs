//! Figure 5 harness: regenerates all three panels of the paper's
//! performance evaluation, printing the same series the paper plots.
//!
//! * left   — OTF2 reader + comm_matrix runtime vs trace size (AMG and
//!            Laghos sweeps); expectation: linear in rows.
//! * center — OTF2 reader strong scaling over reader threads (AMG 128p,
//!            Laghos 256p).
//! * right  — reader memory consumption vs trace size (counting
//!            allocator).
//!
//! ```sh
//! cargo run --release --example fig5_harness
//! ```

use pipit::analysis::{comm_matrix, CommUnit};
use pipit::gen::{self, GenConfig};
use pipit::readers::otf2;
use pipit::util::mem;
use std::time::Instant;

#[global_allocator]
static ALLOC: mem::CountingAlloc = mem::CountingAlloc::new();

fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    (v, t0.elapsed().as_secs_f64() * 1e3)
}

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out/fig5");
    std::fs::create_dir_all(&out)?;

    // ---- left panel: runtime vs trace size --------------------------------
    println!("== Fig 5 (left): reader & comm_matrix runtime vs trace size ==");
    println!("{:<8} {:>10} {:>12} {:>14}", "app", "events", "read (ms)", "comm_mtx (ms)");
    let mut rows_left = Vec::new();
    for app in ["amg", "laghos"] {
        for iters in [5usize, 10, 20, 40, 80] {
            let tr = gen::generate(app, &GenConfig::new(32, iters), 1)?;
            let dir = out.join(format!("{app}_{iters}"));
            otf2::write(&tr, &dir)?;
            let (rd, read_ms) = time_ms(|| otf2::read(&dir, 0).unwrap());
            let (_, cm_ms) = time_ms(|| comm_matrix(&rd, CommUnit::Bytes).unwrap());
            println!("{:<8} {:>10} {:>12.2} {:>14.2}", app, rd.len(), read_ms, cm_ms);
            rows_left.push((app, rd.len(), read_ms, cm_ms));
        }
    }
    // linearity check: time per event roughly constant across the sweep
    for app in ["amg", "laghos"] {
        let per: Vec<f64> = rows_left
            .iter()
            .filter(|(a, _, _, _)| *a == app)
            .map(|(_, n, ms, _)| ms / *n as f64)
            .collect();
        let (lo, hi) = per.iter().fold((f64::MAX, 0f64), |(l, h), &v| (l.min(v), h.max(v)));
        println!("  {app}: read-ns-per-event spread {:.2}x (linear ⇒ small)", hi / lo);
    }

    // ---- center panel: reader strong scaling ------------------------------
    println!("\n== Fig 5 (center): OTF2 reader strong scaling ==");
    let cases = [("amg", 128usize, 40usize), ("laghos", 256, 30)];
    println!("{:<12} {:>8} {:>6} {:>10} {:>9}", "trace", "events", "thr", "read (ms)", "speedup");
    for (app, ranks, iters) in cases {
        let tr = gen::generate(app, &GenConfig::new(ranks, iters), 1)?;
        let dir = out.join(format!("{app}_{ranks}p"));
        otf2::write(&tr, &dir)?;
        let mut base = None;
        for threads in [1usize, 2, 4, 8, 16] {
            // median of 3 runs
            let mut times: Vec<f64> = (0..3)
                .map(|_| time_ms(|| otf2::read(&dir, threads).unwrap()).1)
                .collect();
            times.sort_by(|a, b| a.total_cmp(b));
            let ms = times[1];
            let b = *base.get_or_insert(ms);
            println!(
                "{:<12} {:>8} {:>6} {:>10.2} {:>8.2}x",
                format!("{app}-{ranks}p"),
                tr.len(),
                threads,
                ms,
                b / ms
            );
        }
    }

    // ---- right panel: reader memory consumption ---------------------------
    println!("\n== Fig 5 (right): reader memory vs trace size ==");
    println!("{:<8} {:>10} {:>14} {:>16}", "app", "events", "peak (MiB)", "bytes/event");
    for app in ["amg", "laghos"] {
        for iters in [10usize, 20, 40, 80] {
            let tr = gen::generate(app, &GenConfig::new(32, iters), 1)?;
            let dir = out.join(format!("mem_{app}_{iters}"));
            otf2::write(&tr, &dir)?;
            mem::reset_peak();
            let before = mem::live_bytes();
            let rd = otf2::read(&dir, 1)?;
            let peak = mem::peak_bytes().saturating_sub(before);
            println!(
                "{:<8} {:>10} {:>14.2} {:>16.1}",
                app,
                rd.len(),
                peak as f64 / (1 << 20) as f64,
                peak as f64 / rd.len() as f64
            );
        }
    }
    println!("\nfig5 harness complete (shape targets: linear left panel, rising center speedup, linear right panel)");
    Ok(())
}
