//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! 1. Generates a multi-application trace corpus (5 apps, up to 64 ranks).
//! 2. Round-trips it through every on-disk format (OTF2-sim parallel read,
//!    Projections, Chrome JSON, CSV).
//! 3. Runs the ENTIRE analysis API over the corpus — with the
//!    matrix-profile and time-hist operations executing the AOT-compiled
//!    JAX+Pallas artifacts through PJRT (L1+L2), orchestrated by the L3
//!    coordinator — and validates cross-engine agreement and invariants.
//! 4. Reports the headline metric (paper Fig. 5 shape): reader/op runtime
//!    scaling vs trace size, and parallel-reader speedup.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```
//! Results are recorded in EXPERIMENTS.md.

use pipit::analysis::{self, CommUnit, Metric, PatternConfig};
use pipit::coordinator::AnalysisSession;
use pipit::df::Expr;
use pipit::gen::{self, GenConfig};
use pipit::readers;
use pipit::trace::builder::validate_nesting;
use pipit::util::fmt_ns;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out)?;
    let t_total = Instant::now();

    // ---- 1. corpus ------------------------------------------------------
    println!("== 1. generating corpus ==");
    let specs = [
        ("laghos", 32usize, 20usize, 1usize),
        ("kripke", 32, 10, 1),
        ("tortuga", 64, 12, 1),
        ("loimos", 64, 8, 1),
        ("gol", 8, 40, 1),
        ("axonn", 8, 10, 3),
    ];
    let artifacts = std::path::PathBuf::from("artifacts");
    let mut session = AnalysisSession::new().with_artifacts(&artifacts);
    println!("PJRT runtime loaded: {}", session.uses_hlo());
    assert!(session.uses_hlo(), "run `make artifacts` first — the e2e driver must exercise the HLO path");

    for (app, ranks, iters, variant) in specs {
        let t0 = Instant::now();
        session.generate(app, app, &GenConfig::new(ranks, iters), variant)?;
        let tr = session.get(app)?;
        validate_nesting(tr)?;
        println!(
            "  {app:<8} {} ranks, {} events ({})",
            ranks,
            tr.len(),
            fmt_ns(t0.elapsed().as_nanos() as f64)
        );
    }

    // ---- 2. format round-trips ------------------------------------------
    println!("\n== 2. format round-trips ==");
    let laghos = session.get("laghos")?.clone();
    let otf2_dir = out.join("laghos_otf2");
    readers::otf2::write(&laghos, &otf2_dir)?;
    let t0 = Instant::now();
    let rt_serial = readers::otf2::read(&otf2_dir, 1)?;
    let serial_ns = t0.elapsed().as_nanos() as f64;
    let t0 = Instant::now();
    let rt_parallel = readers::otf2::read(&otf2_dir, 8)?;
    let par_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(rt_serial.len(), laghos.len());
    assert_eq!(rt_parallel.timestamps()?, rt_serial.timestamps()?);
    println!(
        "  otf2: {} events; serial read {} / 8-thread read {} (speedup {:.2}x)",
        laghos.len(),
        fmt_ns(serial_ns),
        fmt_ns(par_ns),
        serial_ns / par_ns
    );

    let gol = session.get("gol")?.clone();
    let chrome_path = out.join("gol.json");
    readers::chrome::write(&gol, &chrome_path)?;
    let rt2 = readers::chrome::read(&chrome_path)?;
    assert_eq!(rt2.len(), gol.len());
    println!("  chrome json: {} events round-tripped", rt2.len());

    let csv_path = out.join("gol.csv");
    readers::csv::write(&gol, &csv_path)?;
    assert_eq!(readers::csv::read(&csv_path)?.len(), gol.len());
    println!("  csv: ok");

    let loimos = session.get("loimos")?.clone();
    let proj_dir = out.join("loimos_proj");
    readers::projections::write(&loimos, &proj_dir, "loimos")?;
    let rt3 = readers::projections::read(&proj_dir, 4)?;
    // recv instants are not representable in projections logs
    assert!(rt3.len() >= loimos.len() * 8 / 10);
    println!("  projections: {} of {} events (recv records dropped by design)", rt3.len(), loimos.len());

    // ---- 3. the full API over the corpus ---------------------------------
    println!("\n== 3. full analysis API ==");

    // 3a. profiles
    let fp = session.flat_profile("tortuga", Metric::ExcTime)?;
    assert_eq!(fp[0].name, "computeRhs");
    println!("  flat_profile[tortuga]: top = {} ({})", fp[0].name, fmt_ns(fp[0].value));

    let t0 = Instant::now();
    let tp = session.time_profile("tortuga", 128, None)?; // HLO path
    println!(
        "  time_profile[tortuga] via PJRT: {} bins x {} funcs, busy {} ({})",
        tp.num_bins(),
        tp.func_names.len(),
        fmt_ns(tp.total()),
        fmt_ns(t0.elapsed().as_nanos() as f64)
    );
    // cross-engine agreement
    let mut t_copy = session.get("tortuga")?.clone();
    let tp_rust = analysis::time_profile(&mut t_copy, 128, Some(63))?;
    let rel = (tp.total() - tp_rust.total()).abs() / tp_rust.total();
    assert!(rel < 1e-3, "HLO and Rust time profiles diverge: {rel}");
    println!("  HLO vs Rust time_profile total agreement: {:.2e} relative", rel);

    // 3b. communication
    let cm = session.comm_matrix("laghos", CommUnit::Bytes)?;
    assert!(cm.diagonal_fraction(4) > 0.99);
    let (hist, _edges) = session.message_histogram("laghos", 10)?;
    let cbp = session.comm_by_process("kripke", CommUnit::Bytes)?;
    let groups: std::collections::BTreeSet<i64> =
        cbp.iter().map(|&(_, s, r)| (s + r) as i64).collect();
    let (cot_counts, _, _) = session.comm_over_time("laghos", 64)?;
    println!(
        "  comm_matrix[laghos]: {}x{}, {:.1}% near-diagonal; histogram {} msgs; kripke groups {}; {} sends over time",
        cm.n(), cm.n(),
        cm.diagonal_fraction(4) * 100.0,
        hist.iter().sum::<u64>(),
        groups.len(),
        cot_counts.iter().sum::<u64>()
    );
    assert_eq!(groups.len(), 3, "kripke must show 3 comm-volume groups");

    // 3c. bottleneck hunting
    let li = session.load_imbalance("loimos", Metric::ExcTime, 5)?;
    let ci = li.iter().find(|r| r.name == "ComputeInteractions()").unwrap();
    assert!(ci.imbalance > 1.3);
    println!(
        "  load_imbalance[loimos]: ComputeInteractions() imbalance {:.2}, top procs {:?}",
        ci.imbalance, ci.top_processes
    );

    let idle = session.idle_time("loimos")?;
    println!(
        "  idle_time[loimos]: most idle = proc {} ({})",
        idle[0].proc,
        fmt_ns(idle[0].idle_ns)
    );

    let t0 = Instant::now();
    let pats = session.detect_pattern("tortuga", Some("time-loop"), &PatternConfig::default())?;
    assert_eq!(pats.len(), 12);
    println!(
        "  pattern_detection[tortuga]: {} iterations found ({})",
        pats.len(),
        fmt_ns(t0.elapsed().as_nanos() as f64)
    );
    // filter one iteration (Fig. 8 workflow)
    session.filter(
        "tortuga",
        "tortuga_iter0",
        &Expr::time_between(pats[0].start, pats[0].end),
    )?;
    println!(
        "  filter[tortuga iter 0]: {} -> {} events",
        session.get("tortuga")?.len(),
        session.get("tortuga_iter0")?.len()
    );

    // matrix profile through PJRT on the activity series
    let tp_gol = session.time_profile("gol", 128, None)?;
    let series: Vec<f64> = {
        // upsample the 128-bin series to cover one AOT call
        let base = tp_gol.bin_totals();
        (0..4200).map(|i| base[i % base.len()]).collect()
    };
    let t0 = Instant::now();
    let prof = session.matrix_profile(&series, 64)?;
    println!(
        "  matrix_profile via PJRT: {} windows, min dist {:.3} ({})",
        prof.len(),
        prof.iter().copied().fold(f64::INFINITY, f64::min),
        fmt_ns(t0.elapsed().as_nanos() as f64)
    );

    // 3d. dependency analyses
    let paths = session.critical_path("gol")?;
    let ts = session.get("gol")?.timestamps()?.to_vec();
    for w in paths[0].rows.windows(2) {
        assert!(ts[w[0] as usize] <= ts[w[1] as usize], "critical path not monotone");
    }
    println!("  critical_path[gol]: {} events on path", paths[0].rows.len());

    let ops = session.lateness("gol")?;
    let by_proc = analysis::lateness_by_process(&ops);
    println!(
        "  lateness[gol]: worst proc {} (max {})",
        by_proc[0].proc,
        fmt_ns(by_proc[0].max_lateness)
    );

    let bd = session.comm_comp_breakdown("axonn")?;
    let mean = analysis::overlap::mean_breakdown(&bd);
    assert!(mean.comp_overlapped > mean.comm, "axonn v3 must overlap most comm");
    println!(
        "  comm_comp_breakdown[axonn v3]: comp {} / overlapped {} / exposed comm {}",
        fmt_ns(mean.comp),
        fmt_ns(mean.comp_overlapped),
        fmt_ns(mean.comm)
    );

    let cct = session.create_cct("tortuga")?;
    println!("  create_cct[tortuga]: {} nodes, {} roots", cct.nodes.len(), cct.roots.len());

    // multi-run over three tortuga scales
    for (i, ranks) in [16usize, 32, 64].iter().enumerate() {
        session.generate(&format!("sweep{i}"), "tortuga", &GenConfig::new(*ranks, 4), 1)?;
    }
    let mr = session.multi_run(&["sweep0", "sweep1", "sweep2"], Metric::ExcTime, 5)?;
    println!("  multi_run[tortuga 16/32/64]:\n{}", indent(&mr.show(), 4));

    // ---- 4. headline metric: scaling shape (Fig. 5) ----------------------
    println!("== 4. headline: op scaling vs trace size ==");
    let mut last = None;
    println!("  {:>10} {:>12} {:>14} {:>14}", "events", "read(ms)", "comm_mtx(ms)", "flat_prof(ms)");
    for iters in [8usize, 16, 32, 64] {
        let tr = gen::generate("amg", &GenConfig::new(16, iters), 1)?;
        let dir = out.join(format!("amg_{iters}"));
        readers::otf2::write(&tr, &dir)?;
        let t0 = Instant::now();
        let rd = readers::otf2::read(&dir, 0)?;
        let read_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let _ = analysis::comm_matrix(&rd, CommUnit::Bytes)?;
        let cm_ms = t0.elapsed().as_secs_f64() * 1e3;
        let mut rd2 = rd.clone();
        let t0 = Instant::now();
        let _ = analysis::flat_profile(&mut rd2, Metric::ExcTime)?;
        let fp_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("  {:>10} {:>12.2} {:>14.2} {:>14.2}", rd.len(), read_ms, cm_ms, fp_ms);
        if let Some((n_prev, read_prev)) = last {
            let size_ratio = rd.len() as f64 / n_prev as f64;
            let time_ratio: f64 = read_ms / read_prev;
            // linear scaling: time ratio tracks size ratio (generously)
            assert!(
                time_ratio < size_ratio * 2.5,
                "reader scaling superlinear: {time_ratio:.2} vs {size_ratio:.2}"
            );
        }
        last = Some((rd.len(), read_ms));
    }

    println!("\nALL E2E CHECKS PASSED in {}", fmt_ns(t_total.elapsed().as_nanos() as f64));
    Ok(())
}

fn indent(s: &str, n: usize) -> String {
    s.lines()
        .map(|l| format!("{:indent$}{l}", "", indent = n))
        .collect::<Vec<_>>()
        .join("\n")
}
