//! Quickstart: the paper's Figure 1 end to end.
//!
//! Reads a tiny CSV trace, shows the uniform events DataFrame, and runs a
//! first analysis — the `foo_bar` example from §III.A.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use pipit::analysis::{self, Metric};
use pipit::trace::Trace;

fn main() -> anyhow::Result<()> {
    // The exact sample trace from the paper's Figure 1 (seconds scale).
    let csv = "\
Timestamp (s), Event Type, Name, Process
0, Enter, main(), 0
1, Enter, foo(), 0
3, Enter, MPI_Send, 0
5, Leave, MPI_Send, 0
8, Enter, baz(), 0
18, Leave, baz(), 0
25, Leave, foo(), 0
100, Leave, main(), 0
0, Enter, main(), 1
2, Enter, foo(), 1
4, Enter, MPI_Recv, 1
7, Leave, MPI_Recv, 1
24, Leave, foo(), 1
100, Leave, main(), 1
";
    let dir = std::env::temp_dir().join("pipit_quickstart");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("foo-bar.csv");
    std::fs::write(&path, csv)?;

    // foo_bar = pipit.Trace.from_csv('foo-bar.csv')
    let mut foo_bar = Trace::from_csv(&path)?;

    // display(foo_bar.events)
    println!("events DataFrame ({} rows):\n", foo_bar.len());
    println!("{}", foo_bar.events.show(8));

    // a first analysis: flat profile + CCT
    let fp = analysis::flat_profile(&mut foo_bar, Metric::ExcTime)?;
    println!("flat profile (exclusive time):");
    for row in &fp {
        println!("  {:<12} {}", row.name, pipit::util::fmt_ns(row.value));
    }

    let cct = analysis::create_cct(&mut foo_bar)?;
    println!("\ncalling context tree:\n{}", cct.render(20));

    // filter (paper §IV.E): process 0 only
    let p0 = foo_bar.filter(&pipit::df::Expr::process_eq(0))?;
    println!("filtered to process 0: {} events", p0.len());
    Ok(())
}
