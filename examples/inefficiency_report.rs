//! Automated inefficiency report — the Scalasca-style analysis built *on
//! top of* the Pipit API (paper §VIII: "we hope that other analysis tools
//! will be developed on top of Pipit"; Table I compares against Scalasca's
//! pattern-based reports).
//!
//! Runs the five wait-state/imbalance detectors over three workloads and
//! prints each report.
//!
//! ```sh
//! cargo run --release --example inefficiency_report
//! ```

use pipit::analysis::{analyze_inefficiencies, ReportConfig};
use pipit::gen::{self, GenConfig};

fn main() -> anyhow::Result<()> {
    let cases = [
        ("gol (halo exchange, stragglers)", "gol", 8usize, 12usize, 1usize),
        ("loimos (imbalanced chares)", "loimos", 64, 6, 1),
        ("axonn v1 (balanced SPMD — expect a clean report)", "axonn", 8, 8, 1),
    ];
    for (label, app, ranks, iters, variant) in cases {
        let mut t = gen::generate(app, &GenConfig::new(ranks, iters), variant)?;
        let rep = analyze_inefficiencies(&mut t, &ReportConfig::default())?;
        println!("### {label}\n");
        println!("{}", rep.render());
    }

    // verify the expected dominant pattern per workload
    let mut gol = gen::generate("gol", &GenConfig::new(8, 12), 1)?;
    let rep = analyze_inefficiencies(&mut gol, &ReportConfig::default())?;
    assert!(
        rep.findings.iter().any(|f| f.pattern == "late-sender"),
        "gol must show late-sender waits"
    );
    let mut loimos = gen::generate("loimos", &GenConfig::new(64, 6), 1)?;
    let rep = analyze_inefficiencies(&mut loimos, &ReportConfig::default())?;
    assert!(
        rep.findings.iter().any(|f| f.pattern == "load-imbalance"),
        "loimos must show load imbalance"
    );
    println!("expected dominant patterns confirmed per workload");
    Ok(())
}
