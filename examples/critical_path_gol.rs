//! Communication-issue case studies (paper §VII.C, Figs. 10–11):
//! * critical-path detection in a 4-process Game of Life trace,
//! * logical-timeline lateness in an 8-process Game of Life trace.
//!
//! ```sh
//! cargo run --release --example critical_path_gol
//! ```

use pipit::analysis::{calculate_lateness, critical_path_analysis, lateness_by_process};
use pipit::gen::{gol, GenConfig};
use pipit::viz::{plot_timeline, TimelineOptions};

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out)?;

    // ---- Fig. 10: critical path, GoL 4p -----------------------------------
    // gol_4 = pipit.Trace.from_otf2('./gol_4')
    let mut gol_4 = gol::generate(&GenConfig::new(4, 6).with_noise(0.02));
    // critical_paths = gol_4.critical_path_analysis()
    let critical_paths = critical_path_analysis(&mut gol_4)?;
    let path = &critical_paths[0];

    // display(critical_paths[0].head(7))
    let table = path.to_table(&gol_4)?;
    println!("critical path dataframe (first 7 rows):\n{}", table.show(7));

    let tbf = path.time_by_function(&gol_4)?;
    println!("time on path by function:");
    for (name, ns) in tbf.iter().take(5) {
        println!("  {:<12} {}", name, pipit::util::fmt_ns(*ns));
    }

    // gol_4.plot_timeline(show_critical_path=True)
    let svg = plot_timeline(
        &mut gol_4,
        &TimelineOptions { critical_path: Some(path.rows.clone()), ..Default::default() },
    )?;
    std::fs::write(out.join("fig10_critical_path_timeline.svg"), svg)?;
    println!("  -> fig10_critical_path_timeline.svg");

    // paper's observation: compute ahead of the first send dominates
    assert_eq!(tbf[0].0, "compute");

    // ---- Fig. 11: lateness, GoL 8p ----------------------------------------
    let mut gol_8 = gol::generate(&GenConfig::new(8, 10).with_noise(0.02));
    let ops = calculate_lateness(&mut gol_8)?;
    let by_proc = lateness_by_process(&ops);
    println!("\nGoL 8p lateness (logical timeline of {} operations):", ops.len());
    println!("{:>8} {:>16} {:>16}", "process", "max lateness", "mean lateness");
    for p in &by_proc {
        println!(
            "{:>8} {:>16} {:>16}",
            p.proc,
            pipit::util::fmt_ns(p.max_lateness),
            pipit::util::fmt_ns(p.mean_lateness)
        );
    }
    // paper: "MPI_Send calls of processes 0 and 4 consistently lag" —
    // our model gives those ranks extra boundary work.
    let top2: Vec<i64> = by_proc.iter().take(2).map(|p| p.proc).collect();
    assert!(top2.contains(&0) && top2.contains(&4), "expected 0 and 4, got {top2:?}");
    println!("\nobservation: processes 0 and 4 are the late ones, as in the paper");

    // logical timeline: step index vs process, colored by lateness, as SVG
    let mut svg = pipit::viz::svg::Svg::new(1000.0, 220.0);
    let max_step = ops.iter().map(|o| o.step).max().unwrap_or(1) as f64;
    let max_late = ops.iter().map(|o| o.lateness).fold(1.0f64, f64::max);
    for op in &ops {
        let x = 40.0 + op.step as f64 / max_step * 920.0;
        let y = 20.0 + op.proc as f64 * 24.0;
        let heat = (op.lateness / max_late * 255.0) as u8;
        svg.rect(
            x,
            y,
            6.0,
            18.0,
            &format!("#{:02x}40{:02x}", heat, 255 - heat),
            Some(&format!("{} step {} lateness {}", op.name, op.step, op.lateness)),
        );
    }
    std::fs::write(out.join("fig11_logical_timeline.svg"), svg.finish())?;
    println!("  -> fig11_logical_timeline.svg");
    Ok(())
}
