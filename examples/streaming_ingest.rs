//! Streaming shard-at-a-time ingest: analyze a trace that is never fully
//! resident in memory, and batch a scaling comparison over many traces.
//!
//! The eager path (`read_auto`) materializes the whole event table before
//! any analysis runs; the `ShardedReader` layer instead yields
//! process-aligned shards incrementally (one OTF2 rank file at a time
//! here), and `exec::stream` feeds them through the worker pool, folding
//! compact partials. Results are bit-identical to the eager path at any
//! thread count — `tests/parity.rs` proves it — and peak memory is
//! bounded by O(workers × shard + results) instead of O(trace).
//!
//! ```sh
//! cargo run --release --example streaming_ingest
//! ```

use pipit::analysis::{CommUnit, Metric};
use pipit::coordinator::AnalysisSession;
use pipit::exec::stream;
use pipit::gen::{self, GenConfig};
use pipit::readers::{open_sharded, otf2};
use pipit::util::fmt_ns;

fn main() -> anyhow::Result<()> {
    // Write a 64-rank trace to disk; from here on we only touch the file.
    let dir = std::env::temp_dir().join("pipit_streaming_example");
    std::fs::create_dir_all(&dir)?;
    let archive = dir.join("laghos64_otf2");
    otf2::write(&gen::generate("laghos", &GenConfig::new(64, 10), 1)?, &archive)?;

    // ---- streaming ingest: pipelined decode→fold over the pool -----------
    // The driver thread only reads raw rank bytes; zlib + varint decode
    // runs as pool tasks overlapping the folds. The flat-profile partials
    // merge in shard-sequence order, so this equals read_auto +
    // flat_profile bitwise no matter how decodes complete.
    let mut reader = open_sharded(&archive)?;
    let (profile, stats) = stream::flat_profile(reader.as_mut(), Metric::ExcTime, 0)?;
    println!("flat profile over a streamed archive (top 5):");
    for row in profile.iter().take(5) {
        println!("  {:<24} {}", row.name, fmt_ns(row.value));
    }
    println!(
        "\ningest instrumentation: {} shards, {} rows total, largest shard {} rows",
        stats.shards, stats.total_rows, stats.max_shard_rows
    );
    println!(
        "  -> peak resident rows were {:.1}% of the trace",
        100.0 * stats.max_shard_rows as f64 / stats.total_rows as f64
    );
    println!(
        "  -> decode pipeline: {:.2} ms decoding on workers / {:.2} ms folding on the driver,\n\
         \x20    peak {} shard(s) in flight (bounded by the worker count)",
        stats.decode_ms, stats.fold_ms, stats.peak_in_flight_shards
    );

    // The census pre-scan: otf2 defs carry per-rank extrema AND a
    // TraceCensus (function ranking, channel endpoint counts, message
    // extrema), so time_profile knows its bins AND its top-k series
    // before any shard decodes — it folds into O(top-k x bins) state,
    // never O(all-functions x bins), never O(segments).
    let mut reader = open_sharded(&archive)?;
    if let Some(census) = reader.census() {
        println!(
            "\npre-scan census: {} blocks, {} functions, {} channels",
            census.blocks.len(),
            census.funcs.as_ref().map_or(0, |f| f.names.len()),
            census.channels.as_ref().map_or(0, |c| c.len()),
        );
    }
    let (tp, stats) = stream::time_profile(reader.as_mut(), 64, Some(8), 0)?;
    println!(
        "census-backed time_profile: {} bins x {} series, peak partial state {} B \
         (vs {} rows streamed), census {}",
        tp.num_bins(),
        tp.func_names.len(),
        stats.peak_partial_bytes,
        stats.total_rows,
        if stats.census { "hit" } else { "miss" },
    );
    println!("  full summary: {}", stats.summary());

    // Windowed pair-and-drain matching: the channel census tells the
    // matcher when a (src, dst, tag) channel has no endpoints left
    // downstream, so completed channels pair and retire during ingest —
    // matcher residency is the open-channel window, not O(endpoints).
    let mut reader = open_sharded(&archive)?;
    let (mm, stats) = stream::match_messages(reader.as_mut(), 0)?;
    println!(
        "\nwindowed match_messages: {} sends / {} recvs matched, \
         peak channel queues {} B (census {})",
        mm.sends.len(),
        mm.recvs.len(),
        stats.peak_channel_queue_bytes,
        if stats.census { "hit" } else { "miss" },
    );

    // The same works through a session: routed analyses on a
    // `load_streamed` entry never materialize the trace.
    let mut s = AnalysisSession::new();
    s.load_streamed("t", &archive)?;
    let m = s.comm_matrix("t", CommUnit::Bytes)?;
    println!(
        "\nstreamed comm_matrix: {0}x{0}, {1} total bytes exchanged",
        m.n(),
        m.total()
    );

    // ---- batch mode: the paper's §V multirun workload --------------------
    // N traces scheduled over one pool, each streamed shard-at-a-time;
    // the aligned table equals per-trace sequential runs exactly.
    let mut paths = Vec::new();
    for ranks in [8usize, 16, 32] {
        let p = dir.join(format!("laghos{ranks}_otf2"));
        otf2::write(&gen::generate("laghos", &GenConfig::new(ranks, 10), 1)?, &p)?;
        paths.push(p);
    }
    let mr = s.run_batch(&paths, Metric::ExcTime, 5)?;
    println!("\nbatched scaling comparison ({} runs):\n", mr.run_labels.len());
    println!("{}", mr.show());
    Ok(())
}
