//! Filtering case studies (paper §VII.B, Figs. 8–9):
//! * pattern detection → filter one Tortuga iteration by time range,
//! * idle-time outliers → filter a Loimos trace by process ids,
//! both visualized with the timeline view.
//!
//! ```sh
//! make artifacts && cargo run --release --example pattern_filter
//! ```

use pipit::analysis::{idle_outliers, PatternConfig};
use pipit::coordinator::AnalysisSession;
use pipit::df::Expr;
use pipit::gen::GenConfig;
use pipit::viz::{plot_timeline, TimelineOptions};

fn main() -> anyhow::Result<()> {
    let out = std::path::PathBuf::from("e2e_out");
    std::fs::create_dir_all(&out)?;
    let mut s = AnalysisSession::new().with_artifacts("artifacts");

    // ---- Fig. 8: pattern detection on Tortuga 16p -------------------------
    // tor_16 = pipit.Trace.from_otf2('./tortuga_16')
    s.generate("tor_16", "tortuga", &GenConfig::new(16, 10), 1)?;
    // patterns = tor_16.detect_pattern(start_event='time-loop')
    let patterns = s.detect_pattern("tor_16", Some("time-loop"), &PatternConfig::default())?;
    println!("Tortuga 16p: {} iterations detected", patterns.len());
    let (start, end) = (patterns[0].start, patterns[0].end);
    println!("  iteration 0: [{start}, {end}] ({})", pipit::util::fmt_ns((end - start) as f64));

    // tor_16.plot_timeline(x_start=start, x_end=end)
    let svg = plot_timeline(
        s.get_mut("tor_16")?,
        &TimelineOptions { x_start: Some(start), x_end: Some(end), ..Default::default() },
    )?;
    std::fs::write(out.join("fig8_one_iteration_timeline.svg"), svg)?;
    println!("  -> fig8_one_iteration_timeline.svg");

    let full = s.get("tor_16")?.len();
    s.filter("tor_16", "iter0", &Expr::time_between(start, end))?;
    println!("  filtered events: {} -> {}", full, s.get("iter0")?.len());

    // ---- Fig. 9: idle outliers on Loimos 64p ------------------------------
    s.generate("loimos_64", "loimos", &GenConfig::new(64, 8), 1)?;
    let (most, least) = idle_outliers(s.get_mut("loimos_64")?, 4, None)?;
    println!("\nLoimos 64p idle time:");
    println!("  most idle:  {:?}", most.iter().map(|r| (r.proc, r.idle_ns as i64)).collect::<Vec<_>>());
    println!("  least idle: {:?}", least.iter().map(|r| (r.proc, r.idle_ns as i64)).collect::<Vec<_>>());

    // reduce the trace to the 8 outlier processes and plot
    let outliers: Vec<i64> = most.iter().chain(least.iter()).map(|r| r.proc).collect();
    s.filter("loimos_64", "outliers", &Expr::process_in(&outliers))?;
    println!(
        "  filtered to 8 outlier processes: {} -> {} events",
        s.get("loimos_64")?.len(),
        s.get("outliers")?.len()
    );
    let svg = plot_timeline(s.get_mut("outliers")?, &TimelineOptions::default())?;
    std::fs::write(out.join("fig9_idle_outliers_timeline.svg"), svg)?;
    println!("  -> fig9_idle_outliers_timeline.svg");

    // paper's claim: outlier groups differ visibly in activity
    let most_idle_frac = most[0].fraction;
    let least_idle_frac = least[0].fraction;
    assert!(
        most_idle_frac > least_idle_frac + 0.05,
        "idle outliers should separate: {most_idle_frac} vs {least_idle_frac}"
    );
    Ok(())
}
