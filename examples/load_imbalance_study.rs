//! Load-imbalance case study (paper §VII.A, Fig. 7): Loimos on 128
//! processes, top-5 most time-consuming functions with their imbalance and
//! most-loaded processes.
//!
//! ```sh
//! cargo run --release --example load_imbalance_study
//! ```

use pipit::analysis::{load_imbalance, Metric};
use pipit::gen::{loimos, GenConfig};

fn main() -> anyhow::Result<()> {
    // loimos_128 = pipit.Trace.from_projections('loimos_128')
    let mut loimos_128 = loimos::generate(&GenConfig::new(128, 10));
    println!(
        "Loimos 128p: {} events, {} processes\n",
        loimos_128.len(),
        loimos_128.num_processes()?
    );

    // loimos_128.load_imbalance(num_processes=5) . sort_values(by='time.exc') . head(5)
    let rows = load_imbalance(&mut loimos_128, Metric::ExcTime, 5)?;
    println!(
        "{:<58} {:>18} {:>28} {:>15}",
        "", "time.exc.imbalance", "Top processes", "time.exc.mean"
    );
    for r in rows.iter().filter(|r| r.name != "main").take(5) {
        let procs: Vec<String> = r.top_processes.iter().map(|p| p.to_string()).collect();
        println!(
            "{:<58} {:>18.6} {:>28} {:>15.6e}",
            truncate(&r.name, 57),
            r.imbalance,
            format!("[{}]", procs.join(", ")),
            r.mean
        );
    }

    // The paper's observations, checked programmatically:
    let ci = rows.iter().find(|r| r.name == "ComputeInteractions()").unwrap();
    let rv = rows
        .iter()
        .find(|r| r.name.starts_with("ReceiveVisitMessages"))
        .unwrap();
    println!("\nobservations (paper §VII.A):");
    println!(
        "  * ComputeInteractions() is the most time consuming entry (mean {:.3e} ns) with imbalance {:.2}",
        ci.mean, ci.imbalance
    );
    println!(
        "  * ReceiveVisitMessages(...) shows the highest imbalance: {:.2}",
        rv.imbalance
    );
    let overlap: Vec<i64> = ci
        .top_processes
        .iter()
        .filter(|p| rv.top_processes.contains(p))
        .copied()
        .collect();
    println!("  * overloaded processes shared across functions: {overlap:?}");
    assert!(rv.imbalance >= 1.2);
    assert!(!overlap.is_empty(), "paper: top processes are common across functions");
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n - 1])
    }
}
